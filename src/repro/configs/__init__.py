"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full production config;
``smoke_variant(cfg)`` derives the reduced CPU-testable variant
(<=2 pattern repeats, d_model<=512, <=4 experts) used by smoke tests.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from . import (
    gemma2_2b,
    musicgen_large,
    qwen3_moe_30b_a3b,
    mamba2_1_3b,
    yi_34b,
    internlm2_1_8b,
    nemotron_4_15b,
    llava_next_mistral_7b,
    recurrentgemma_9b,
    grok_1_314b,
)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma2_2b,
        musicgen_large,
        qwen3_moe_30b_a3b,
        mamba2_1_3b,
        yi_34b,
        internlm2_1_8b,
        nemotron_4_15b,
        llava_next_mistral_7b,
        recurrentgemma_9b,
        grok_1_314b,
    )
}

ARCH_IDS = tuple(sorted(REGISTRY))

#: Default per-analyzed-frame context depth (tokens) when a model serves as
#: a camera-frame analysis program: the prefill each frame's caption/VQA
#: pass runs.  Lives with the registry (it is a property of how each model
#: is deployed, not of the fleet layer); ``core.calibration`` reads it to
#: build the default workload set.  Omitted archs (audio gen, 314B-scale)
#: are not sensible frame analyzers / fit no catalog type.
DEFAULT_TOKENS_PER_FRAME: dict[str, int] = {
    "gemma2-2b": 2048,
    "internlm2-1.8b": 512,
    "mamba2-1.3b": 1024,
    "llava-next-mistral-7b": 2048,
    "recurrentgemma-9b": 1024,
    "nemotron-4-15b": 2048,
}


def default_tokens_per_frame(arch_id: str) -> int:
    try:
        return DEFAULT_TOKENS_PER_FRAME[arch_id]
    except KeyError:
        raise KeyError(
            f"{arch_id!r} has no frame-analysis deployment default; known: "
            f"{tuple(sorted(DEFAULT_TOKENS_PER_FRAME))}"
        ) from None


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}") from None


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 1-2 groups, d_model<=512, <=4 experts."""
    pattern = cfg.layer_pattern
    groups = min(cfg.num_groups, 2 if len(pattern) == 1 else 1)
    d_model = min(cfg.d_model, 256)
    heads = max(1, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=groups * len(pattern),
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64 if cfg.num_heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        lru_width=min(cfg.resolved_lru_width, d_model) if cfg.lru_width else None,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_chunk=16,
        vision_tokens=min(cfg.vision_tokens, 16),
    )
    if cfg.num_experts:
        updates.update(num_experts=min(cfg.num_experts, 4),
                       experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.window_pattern is not None:
        updates["window_pattern"] = tuple(
            (min(w, 16) if w else None) for w in cfg.window_pattern
        )
    if cfg.long_context_window:
        updates["long_context_window"] = 16
    return dataclasses.replace(cfg, **updates)
