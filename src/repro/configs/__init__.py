"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full production config;
``smoke_variant(cfg)`` derives the reduced CPU-testable variant
(<=2 pattern repeats, d_model<=512, <=4 experts) used by smoke tests.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from . import (
    gemma2_2b,
    musicgen_large,
    qwen3_moe_30b_a3b,
    mamba2_1_3b,
    yi_34b,
    internlm2_1_8b,
    nemotron_4_15b,
    llava_next_mistral_7b,
    recurrentgemma_9b,
    grok_1_314b,
)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma2_2b,
        musicgen_large,
        qwen3_moe_30b_a3b,
        mamba2_1_3b,
        yi_34b,
        internlm2_1_8b,
        nemotron_4_15b,
        llava_next_mistral_7b,
        recurrentgemma_9b,
        grok_1_314b,
    )
}

ARCH_IDS = tuple(sorted(REGISTRY))


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}") from None


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 1-2 groups, d_model<=512, <=4 experts."""
    pattern = cfg.layer_pattern
    groups = min(cfg.num_groups, 2 if len(pattern) == 1 else 1)
    d_model = min(cfg.d_model, 256)
    heads = max(1, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=groups * len(pattern),
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64 if cfg.num_heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        lru_width=min(cfg.resolved_lru_width, d_model) if cfg.lru_width else None,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_chunk=16,
        vision_tokens=min(cfg.vision_tokens, 16),
    )
    if cfg.num_experts:
        updates.update(num_experts=min(cfg.num_experts, 4),
                       experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.window_pattern is not None:
        updates["window_pattern"] = tuple(
            (min(w, 16) if w else None) for w in cfg.window_pattern
        )
    if cfg.long_context_window:
        updates["long_context_window"] = 16
    return dataclasses.replace(cfg, **updates)
