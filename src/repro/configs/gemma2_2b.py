"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

Source: Gemma 2 technical report [arXiv:2408.00118]. 26 layers, d_model
2304, 8 query heads with GQA kv=4 (head_dim 256), d_ff 9216 (GeGLU),
vocab 256000, sliding window 4096 on alternating (local) layers, attention
logit softcap 50, final logit softcap 30, embeddings scaled by sqrt(d).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=("attention", "attention"),
    window_pattern=(4096, None),  # local, global alternating
    rope_theta=10_000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_activation="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    embed_scale_by_sqrt_dim=True,
    # long_500k: local layers are windowed natively; global layers hold the
    # full KV, sharded over the data axis (DESIGN.md long-context policy).
)
