"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attention per 3.

Source: Griffin / RecurrentGemma [arXiv:2402.19427]. 38 layers, d_model
4096, 16 heads MQA kv=1 (head_dim 256), d_ff 12288 (GeGLU), vocab 256000,
local attention window 2048, RG-LRU width 4096.

38 is not divisible by 3, so the repeating pattern is expressed as a
19-slot super-pattern (6 x [rec, rec, attn] + 1 rec) repeated twice —
exactly 38 layers with the paper's 2:1 recurrent:attention mix.
"""
from repro.models.config import ModelConfig

_SUPER = ("recurrent", "recurrent", "attention") * 6 + ("recurrent",)
_WINDOWS = tuple(2048 if k == "attention" else None for k in _SUPER)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    layer_pattern=_SUPER,
    window_pattern=_WINDOWS,
    mlp_activation="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    embed_scale_by_sqrt_dim=True,
    lru_width=4096,
    rglru_conv_width=4,
    # Sub-quadratic natively (window 2048 + recurrent state).
)
