"""nemotron-4-15b [dense] — GQA with squared-ReLU MLP.

Source: Nemotron-4 [arXiv:2402.16819]. 32 layers, d_model 6144, 48 heads
GQA kv=8 (head_dim 128), d_ff 24576 (non-gated, squared ReLU),
vocab 256000, untied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=256_000,
    layer_pattern=("attention",),
    mlp_activation="relu2",
    gated_mlp=False,
    tie_embeddings=False,
    long_context_window=4096,  # -sw variant switch for long_500k
)
