"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
artifacts/dryrun/*.json. Hand-written sections (§Paper-claims, §Perf,
§Beyond-paper) live between markers and are preserved.

Usage: PYTHONPATH=src python scripts/gen_experiments.py
"""
import glob
import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(mesh):
    recs = [json.load(open(p)) for p in glob.glob(os.path.join(ART, f"*__{mesh}.json"))]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return recs


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024 or unit == "PB":
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table():
    lines = [
        "| arch | shape | mesh | compile | HBM/dev (args+temp) | global FLOPs | coll bytes/dev | top collective |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for mesh in ("16x16", "2x16x16"):
        for r in load(mesh):
            m = r["memory"]
            hbm = (m.get("argument_size_in_bytes") or 0) + (
                m.get("temp_size_in_bytes") or 0)
            colls = {k: v for k, v in r["collectives"].items() if k != "total"}
            top = max(colls, key=lambda k: colls[k]["bytes"]) if colls else "-"
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | "
                f"{r['compile_s']:.0f}s | {fmt_bytes(hbm)} | "
                f"{r['hlo_flops']:.2e} | "
                f"{fmt_bytes(r['collectives']['total']['bytes'])} | {top} |"
            )
    return "\n".join(lines)


def roofline_table():
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | MODEL_FLOPS | useful frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load("16x16"):
        t = r["roofline"]
        dom = t["dominant"].replace("_s", "")
        hint = {
            "memory": "fuse/keep activations in VMEM (flash kernels), drop fp32 intermediates, shard idle axes",
            "compute": "already compute-bound: raise MFU via MXU-aligned tiles / less remat",
            "collective": "reshard to cut all-gathers; overlap collectives with compute",
        }[dom]
        uf = r.get("useful_flops_frac")
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{t['compute_s']*1e3:.2f}ms | {t['memory_s']*1e3:.2f}ms | "
            f"{t['collective_s']*1e3:.2f}ms | **{dom}** | "
            f"{r['model_flops']:.2e} | {uf:.2f} | {hint} |"
            if uf is not None else
            f"| {r['arch']} | {r['shape']} | "
            f"{t['compute_s']*1e3:.2f}ms | {t['memory_s']*1e3:.2f}ms | "
            f"{t['collective_s']*1e3:.2f}ms | **{dom}** | "
            f"{r['model_flops']:.2e} | - | {hint} |"
        )
    return "\n".join(lines)


def main():
    text = open(EXP).read() if os.path.exists(EXP) else ""
    dr = ("<!-- DRYRUN:BEGIN -->\n" + dryrun_table() + "\n<!-- DRYRUN:END -->")
    rf = ("<!-- ROOFLINE:BEGIN -->\n" + roofline_table()
          + "\n<!-- ROOFLINE:END -->")
    if "<!-- DRYRUN:BEGIN -->" in text:
        text = re.sub(r"<!-- DRYRUN:BEGIN -->.*?<!-- DRYRUN:END -->", dr,
                      text, flags=re.S)
        text = re.sub(r"<!-- ROOFLINE:BEGIN -->.*?<!-- ROOFLINE:END -->", rf,
                      text, flags=re.S)
        open(EXP, "w").write(text)
    else:
        print("markers not found; printing tables")
        print(dr)
        print(rf)
    n16 = len(load("16x16"))
    n2 = len(load("2x16x16"))
    print(f"regenerated: {n16} single-pod rows, {n2} multi-pod rows")


if __name__ == "__main__":
    main()
