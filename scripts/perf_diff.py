"""Diff two dry-run artifacts (before/after a perf change).

Usage: python scripts/perf_diff.py before.json after.json
"""
import json
import sys


def main() -> None:
    a = json.load(open(sys.argv[1]))
    b = json.load(open(sys.argv[2]))
    print(f"{'metric':28s} {'before':>14s} {'after':>14s} {'delta':>8s}")
    rows = [
        ("flops/dev", a["hlo_flops_per_device"], b["hlo_flops_per_device"]),
        ("bytes/dev", a["hlo_bytes_per_device"], b["hlo_bytes_per_device"]),
        ("coll bytes/dev", a["collectives"]["total"]["bytes"],
         b["collectives"]["total"]["bytes"]),
        ("t_compute ms", a["roofline"]["compute_s"] * 1e3,
         b["roofline"]["compute_s"] * 1e3),
        ("t_memory ms", a["roofline"]["memory_s"] * 1e3,
         b["roofline"]["memory_s"] * 1e3),
        ("t_collective ms", a["roofline"]["collective_s"] * 1e3,
         b["roofline"]["collective_s"] * 1e3),
        ("temp bytes", a["memory"]["temp_size_in_bytes"] or 0,
         b["memory"]["temp_size_in_bytes"] or 0),
    ]
    for name, x, y in rows:
        delta = (y - x) / x if x else float("nan")
        print(f"{name:28s} {x:14.4g} {y:14.4g} {delta:+8.1%}")
    for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"):
        x = a["collectives"].get(op, {}).get("bytes", 0)
        y = b["collectives"].get(op, {}).get("bytes", 0)
        if x or y:
            d = (y - x) / x if x else float("nan")
            print(f"  {op:26s} {x:14.4g} {y:14.4g} {d:+8.1%}")
    print(f"dominant: {a['roofline']['dominant']} -> {b['roofline']['dominant']}")


if __name__ == "__main__":
    main()
