"""Diff two perf artifacts (before/after a perf change).

Usage:
  python scripts/perf_diff.py before.json after.json

Handles two artifact shapes:
  * dry-run artifacts (launch/dryrun.py output): roofline + collective
    metric comparison, as before;
  * benchmark row artifacts ({"meta": ..., "rows": {name: {"us": ...}}}),
    e.g. BENCH_solver.json emitted by benchmarks/solver_scaling.py or
    BENCH_replan.json from benchmarks/churn_replan.py — rows are matched
    by name and wall-time deltas reported, so solver PRs can diff their
    timings against the recorded baseline.  Numeric headline metrics the
    emitter stored in "meta" (e.g. the re-plan artifact's
    speedup_warm_vs_cold / max_certified_gap) are diffed alongside the
    rows; scripts/check_bench.py gates the same keys against floors.
    Billed-cost metrics (the lifecycle artifact's "billed_*" keys and
    degraded-time counters from benchmarks/lifecycle.py) get their own
    dollar-formatted section, so billing-engine PRs can eyeball whether a
    change moved the *bill*, not just the wall time.  Spot/preemption
    metrics (BENCH_spot.json's preemption counts, degraded-time splits,
    and risk-aware savings) likewise get a dedicated section, as do the
    storm-harness SLA metrics (BENCH_storm.json's blackout stream-second
    splits, notice-conversion rate, utility penalties, and per-tier
    violation counts) and the sharded-controller scaling metrics
    (BENCH_shard.json's per-event latencies, vmap-repair speedup, and
    flat-vs-sharded cost parity), the shard event-pipeline metrics
    (batched-vs-serial apply wall-times and bit-identity delta,
    one-dispatch certification speedup, and the pipeline stats counters)
    and the branch-and-price solver metrics
    (BENCH_solver.json's certified colgen/enumeration gaps, batched
    pricing speedup, and kernel bit-equivalence probe).  The
    calibrated-requirements metrics (BENCH_calibration.json's device
    split, kernel→dollars saving, and artifact freshness/bit-identity
    probes) close the list.
"""
import json
import sys

# Spot-specific key prefixes only: BENCH_lifecycle.json's pre-existing
# "acting_billed_overhead" must stay in the general meta section, so the
# spot benchmark's acting keys are matched by their full spot-only names.
_SPOT_PREFIXES = (
    "preempt",
    "risk_aware_",
    "risk_vs_",
    "naive_spot_",
    "acting_join_degraded_cut",
    "acting_unreliable_spares",
    "trace_shocks",
)


# Storm-harness SLA metrics (BENCH_storm.json).  "tiered_billed_overhead"
# is listed here by full name so it lands with its storm siblings rather
# than in the dollar-formatted billed section (it is a ratio, not a bill).
_STORM_PREFIXES = (
    "blackout_",
    "drain_blackout_",
    "gold_violations",
    "sla_violations_",
    "utility_penalty",
    "notice_conversion",
    "notice_victim_steps",
    "trace_notices",
    "trace_kills",
    "tiered_billed_overhead",
    "qos_",
)


# Sharded-controller scaling metrics (BENCH_shard.json).
_SHARD_PREFIXES = (
    "sharded_",
    "vmap_repair_",
    "flat_vs_sharded",
    "mean_warm_event",
    "single_cell_cost",
    "cost_ratio_n500",
)


# Shard event-pipeline metrics (BENCH_shard.json, PR 9): batched vs
# serial apply wall-times and bit-identity delta, one-dispatch vs
# per-cell certification, and the pipeline's observability counters
# (`ShardedController.stats()` surfaced into the artifact meta).
_SHARD_PIPELINE_PREFIXES = (
    "batched_apply_",
    "serial_apply_",
    "batched_certify_",
    "serial_certify_",
    "pipeline_",
)


# Branch-and-price solver metrics (BENCH_solver.json): certified gaps,
# the batched-pricing speedup, and the kernel bit-equivalence probe.
_COLGEN_PREFIXES = (
    "colgen_",
    "arcflow_budget_gap",
    "pricing_batched_speedup",
    "pricing_bitident_mismatch",
)


# Calibrated-requirements metrics (BENCH_calibration.json): device-class
# split of the calibrated mix, the kernel→dollars saving, and the
# artifact freshness / impl bit-identity probes.
_CALIBRATION_PREFIXES = (
    "calibrated_",
    "accel2x_",
    "calib_",
    "accelerator_speedup",
)


def _is_billed_key(k: str) -> bool:
    return k.startswith("billed_") or k.startswith("degraded_seconds")


def _is_colgen_key(k: str) -> bool:
    return k.startswith(_COLGEN_PREFIXES)


def _is_calibration_key(k: str) -> bool:
    return k.startswith(_CALIBRATION_PREFIXES)


def _is_spot_key(k: str) -> bool:
    return k.startswith(_SPOT_PREFIXES)


def _is_storm_key(k: str) -> bool:
    return k.startswith(_STORM_PREFIXES)


def _is_shard_key(k: str) -> bool:
    return k.startswith(_SHARD_PREFIXES) and not _is_shard_pipeline_key(k)


def _is_shard_pipeline_key(k: str) -> bool:
    return k.startswith(_SHARD_PIPELINE_PREFIXES)


def _diff_section(a: dict, b: dict, predicate, label: str, fmt) -> None:
    """One meta-metric section: keys matching ``predicate``, rows
    rendered by ``fmt(key, before, after, delta) -> str``."""
    am, bm = a.get("meta", {}), b.get("meta", {})
    keys = sorted(k for k in set(am) | set(bm) if predicate(k))
    width = 34 if not keys else max(34, max(map(len, keys)))
    shown = False
    for k in keys:
        x, y = am.get(k), bm.get(k)
        if not (isinstance(x, (int, float)) and isinstance(y, (int, float))):
            continue
        if not shown:
            print(
                f"{label:{width}s} {'before':>12s} {'after':>12s} "
                f"{'delta':>8s}"
            )
            shown = True
        delta = (y - x) / x if x else float("nan")
        print(f"{k:{width}s} {fmt(k, x, y, delta)}")
    if shown:
        print()


def diff_spot(a: dict, b: dict) -> None:
    _diff_section(
        a,
        b,
        _is_spot_key,
        "spot/preemption metric",
        lambda k, x, y, d: f"{x:12.4g} {y:12.4g} {d:+8.1%}",
    )


def diff_storm(a: dict, b: dict) -> None:
    def fmt(k, x, y, d):
        unit = "s" if k.startswith("blackout_seconds") else " "
        return f"{x:11.4g}{unit} {y:11.4g}{unit} {d:+8.1%}"

    _diff_section(a, b, _is_storm_key, "storm/SLA metric", fmt)


def diff_shard(a: dict, b: dict) -> None:
    def fmt(k, x, y, d):
        unit = "s" if k.endswith("_s") else " "
        return f"{x:11.4g}{unit} {y:11.4g}{unit} {d:+8.1%}"

    _diff_section(a, b, _is_shard_key, "shard scaling metric", fmt)


def diff_shard_pipeline(a: dict, b: dict) -> None:
    def fmt(k, x, y, d):
        unit = "s" if k.endswith("_s") else "x" if k.endswith("speedup") else " "
        return f"{x:11.4g}{unit} {y:11.4g}{unit} {d:+8.1%}"

    _diff_section(a, b, _is_shard_pipeline_key, "shard pipeline metric", fmt)


def diff_billed(a: dict, b: dict) -> None:
    def fmt(k, x, y, d):
        unit = "s" if k.startswith("degraded") else "$"
        return f"{unit}{x:11.2f} {unit}{y:11.2f} {d:+8.1%}"

    _diff_section(a, b, _is_billed_key, "billed-cost metric", fmt)


def diff_colgen(a: dict, b: dict) -> None:
    def fmt(k, x, y, d):
        unit = "x" if k.endswith("speedup") else " "
        return f"{x:11.4g}{unit} {y:11.4g}{unit} {d:+8.1%}"

    _diff_section(a, b, _is_colgen_key, "branch-and-price metric", fmt)


def diff_calibration(a: dict, b: dict) -> None:
    def fmt(k, x, y, d):
        unit = "$" if "cost" in k and "saving" not in k else " "
        return f"{x:11.4g}{unit} {y:11.4g}{unit} {d:+8.1%}"

    _diff_section(a, b, _is_calibration_key, "calibrated-requirements metric", fmt)


def diff_meta(a: dict, b: dict) -> None:
    diff_billed(a, b)
    diff_spot(a, b)
    diff_storm(a, b)
    diff_shard(a, b)
    diff_shard_pipeline(a, b)
    diff_colgen(a, b)
    diff_calibration(a, b)
    am, bm = a.get("meta", {}), b.get("meta", {})
    keys = [
        k
        for k in sorted(set(am) | set(bm))
        if not _is_billed_key(k)
        and not _is_spot_key(k)
        and not _is_storm_key(k)
        and not _is_shard_key(k)
        and not _is_shard_pipeline_key(k)
        and not _is_colgen_key(k)
        and not _is_calibration_key(k)
        and (
            isinstance(am.get(k), (int, float))
            or isinstance(bm.get(k), (int, float))
        )
    ]
    shown = False
    for k in keys:
        x, y = am.get(k), bm.get(k)
        if not (isinstance(x, (int, float)) and isinstance(y, (int, float))):
            continue
        if not shown:
            print(f"{'meta metric':34s} {'before':>12s} {'after':>12s} {'delta':>8s}")
            shown = True
        delta = (y - x) / x if x else float("nan")
        print(f"{k:34s} {x:12.4g} {y:12.4g} {delta:+8.1%}")
    if shown:
        print()


def diff_rows(a: dict, b: dict) -> None:
    diff_meta(a, b)
    rows_a, rows_b = a["rows"], b["rows"]
    names = sorted(set(rows_a) | set(rows_b))
    print(f"{'row':34s} {'before us':>12s} {'after us':>12s} {'delta':>8s}")
    for name in names:
        x = rows_a.get(name, {}).get("us")
        y = rows_b.get(name, {}).get("us")
        if x is None or y is None:
            status = "added" if x is None else "removed"
            x_s = f"{x:12.1f}" if x is not None else f"{'-':>12s}"
            y_s = f"{y:12.1f}" if y is not None else f"{'-':>12s}"
            print(f"{name:34s} {x_s} {y_s} {status:>8s}")
            continue
        delta = (y - x) / x if x else float("nan")
        print(f"{name:34s} {x:12.1f} {y:12.1f} {delta:+8.1%}")


def diff_dryrun(a: dict, b: dict) -> None:
    print(f"{'metric':28s} {'before':>14s} {'after':>14s} {'delta':>8s}")
    rows = [
        ("flops/dev", a["hlo_flops_per_device"], b["hlo_flops_per_device"]),
        ("bytes/dev", a["hlo_bytes_per_device"], b["hlo_bytes_per_device"]),
        ("coll bytes/dev", a["collectives"]["total"]["bytes"],
         b["collectives"]["total"]["bytes"]),
        ("t_compute ms", a["roofline"]["compute_s"] * 1e3,
         b["roofline"]["compute_s"] * 1e3),
        ("t_memory ms", a["roofline"]["memory_s"] * 1e3,
         b["roofline"]["memory_s"] * 1e3),
        ("t_collective ms", a["roofline"]["collective_s"] * 1e3,
         b["roofline"]["collective_s"] * 1e3),
        ("temp bytes", a["memory"]["temp_size_in_bytes"] or 0,
         b["memory"]["temp_size_in_bytes"] or 0),
    ]
    for name, x, y in rows:
        delta = (y - x) / x if x else float("nan")
        print(f"{name:28s} {x:14.4g} {y:14.4g} {delta:+8.1%}")
    for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"):
        x = a["collectives"].get(op, {}).get("bytes", 0)
        y = b["collectives"].get(op, {}).get("bytes", 0)
        if x or y:
            d = (y - x) / x if x else float("nan")
            print(f"  {op:26s} {x:14.4g} {y:14.4g} {d:+8.1%}")
    print(f"dominant: {a['roofline']['dominant']} -> {b['roofline']['dominant']}")


def main() -> None:
    a = json.load(open(sys.argv[1]))
    b = json.load(open(sys.argv[2]))
    if "rows" in a and "rows" in b:
        diff_rows(a, b)
    elif "rows" in a or "rows" in b:
        sys.exit(
            "artifact shape mismatch: one file is a benchmark-row artifact "
            "and the other a dry-run artifact — diff like with like"
        )
    else:
        diff_dryrun(a, b)


if __name__ == "__main__":
    main()
