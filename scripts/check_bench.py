"""Benchmark regression gate: fail when a stored floor is violated.

Usage:
  python scripts/check_bench.py BENCH_replan.json [more.json ...]

Each known artifact carries floors on headline metrics recorded in its
``meta`` block (see ``benchmarks/common.write_json``).  The floors are
deliberately conservative — far below the measured values on the
recording machine — so the gate trips on real regressions (an algorithmic
change that quietly kills the warm-start path), not on machine noise.
Wired into ``benchmarks/run.py``: gated suites run the check after
emitting their artifact.

Exit code 0 = all floors met; 1 = violation or malformed artifact.
"""
from __future__ import annotations

import json
import sys

#: artifact name -> {meta key: (comparator, floor/ceiling, description)}.
FLOORS: dict[str, dict[str, tuple[str, float, str]]] = {
    "BENCH_replan.json": {
        # Acceptance: warm-start single-stream re-plan >= 5x faster than a
        # from-scratch solve on the 500-stream churn benchmark (measured
        # ~50x on the recording machine).
        "speedup_warm_vs_cold": (">=", 5.0, "warm-start speedup floor"),
        # Warm plans must stay within their certified optimality gap
        # budget (the controller's fallback threshold plus slack).
        "max_certified_gap": ("<=", 0.15, "certified gap ceiling"),
        # And must not cost materially more than the cold plans they avoid.
        "cost_ratio_mean": ("<=", 1.10, "warm/cold cost-ratio ceiling"),
    },
    "BENCH_lifecycle.json": {
        # Acceptance: the timed refactor must not perturb PR-3 snapshot
        # costing — PinningPolicy with per-second billing and zero boot
        # latency reproduces the stored BENCH_policy.json final cost bit
        # for bit ...
        "pinning_bitident_delta": ("<=", 0.0, "snapshot cost bit-identity"),
        # ... and its billed total matches the instantaneous integral
        # (same math, different summation grouping, hence the epsilon).
        "persecond_billed_integral_delta": ("<=", 1e-9, "billed == integral"),
        # Quantized billing only rounds up.
        "hourly_premium": (">=", 0.0, "hourly round-up premium sign"),
        # Acceptance: acting on the forecast (warm spares) must cut the
        # post-join degraded time vs reactive pinning (measured ~100%) ...
        "degraded_reduction": (">=", 0.2, "post-join degraded-time cut"),
        # ... at no more than 5% billed-cost overhead ...
        "acting_billed_overhead": ("<=", 0.05, "pre-provisioning overhead"),
        # ... and billing-aware consolidation never ends with a larger
        # bill than the billing-blind policy under hourly billing.
        "billing_aware_excess": ("<=", 1e-9, "billing-aware consolidation bill"),
    },
    "BENCH_spot.json": {
        # Acceptance: risk-aware spot allocation must bill >= 10% less
        # than all-on-demand on the seeded preemption-heavy trace
        # (measured ~65% with the stable pool at 45% of on-demand) ...
        "risk_aware_billed_saving": (">=", 0.10, "risk-aware bill cut vs on-demand"),
        # ... while its preemption-caused degraded stream-seconds stay no
        # worse than naive all-spot's (the replay is deterministic, so
        # equality-up-to-eps is a real bound, not noise) ...
        "risk_vs_naive_degraded_excess": ("<=", 1e-9, "risk-aware degraded bound"),
        # ... and naive all-spot must demonstrably lose on degraded time
        # (measured ~58,000 s of preemption downtime on this trace) ...
        "preempt_degraded_seconds_naive_spot": (">=", 600.0, "naive pays in downtime"),
        # ... an all-on-demand fleet rides out every shock unscathed ...
        "preemptions_ondemand": ("<=", 0.0, "on-demand is never preempted"),
        # ... and the acting autoscaler never holds a spare from a pool
        # above its hazard tolerance (no flaky-spot spares).
        "acting_unreliable_spares": ("<=", 0.0, "no unreliable warm spares"),
    },
    "BENCH_storm.json": {
        # Acceptance: the tiered run must end the seeded storm with ZERO
        # GOLD SLA violations (GOLD budgets are never spent on blackout) ...
        "gold_violations_tiered": ("<=", 0.0, "GOLD never violated"),
        # ... total blackout stream-seconds must drop >= 60% vs the PR-5
        # risk-aware baseline on the identical trace (measured ~88%) ...
        "blackout_drop_vs_baseline": (">=", 0.60, "blackout cut vs PR-5 baseline"),
        # ... at <= 10% billed-cost overhead (measured ~-5%: degraded
        # streams shrink, so the tiered fleet actually bills less) ...
        "tiered_billed_overhead": ("<=", 0.10, "billed-cost overhead ceiling"),
        # ... >= 80% of victim-bearing notice steps must drain tail-free
        # (kill converted to an ordinary migration, measured 100%) ...
        "notice_conversion": (">=", 0.80, "notice-to-migration conversion"),
        # ... on a trace that actually exercises the drain path ...
        "notice_victim_steps": (">=", 1.0, "trace exercises noticed victims"),
        # ... and degraded-mode service must cost less total utility than
        # the baseline's pure-blackout penalty (measured ~0.83).
        "utility_penalty_ratio": ("<=", 1.0, "degraded beats blackout on utility"),
        # The cost-vs-QoS sweep must actually trace a curve (>= 3 swept
        # utility-price points, including the headline run at scale 1).
        "qos_curve_points": (">=", 3.0, "cost-vs-QoS curve is populated"),
    },
    "BENCH_shard.json": {
        # Acceptance: the sharded replay must actually run at target
        # scale — a 100k-stream fleet over >= 256 cells completes its
        # churn trace through the batched event pipeline ...
        "sharded_streams": (">=", 100_000.0, "replay reaches 100k streams"),
        "sharded_cells": (">=", 256.0, "cell partition is real"),
        # ... with mean warm per-event latency under 100 ms ...
        "mean_warm_event_us": ("<=", 100_000.0, "warm event latency ceiling"),
        # ... the batched `apply_events` pipeline >= 3x faster than the
        # serial per-event loop on the identical trace ...
        "batched_apply_speedup": (">=", 3.0, "batched apply speedup floor"),
        # ... while staying bit-identical to it (max abs difference over
        # per-event hourly costs + certified lower bounds and the final
        # placements/instances/uids/billed total) ...
        "batched_apply_delta": ("<=", 0.0, "batched apply bit-identity"),
        # ... one stacked column-generation run certifies all 512 cells
        # >= 2x faster than the serial per-cell dual-price loop, and in
        # bounded wall-time once the shared column pool is warm ...
        "batched_certify_speedup": (">=", 2.0, "one-dispatch certify speedup"),
        "batched_certify_s": ("<=", 5.0, "warm certification wall ceiling"),
        # ... while the flat controller on the identical 5k probe is
        # already >= 10x slower per warm event (measured ~80x), which is
        # why a flat 100k replay is documented infeasible, not run ...
        "flat_vs_sharded_event_ratio_5k": (">=", 10.0, "flat probe slowdown"),
        # ... one vmapped `_pack_core` dispatch repairs >= 64 cells >= 3x
        # faster than packing them serially with the numpy reference
        # (measured ~4x at 512 cells — the shared pad shape wastes more
        # work at 512 cells than the ~6x recorded at 256) ...
        "vmap_repair_cells": (">=", 64.0, "batched repair batch width"),
        "vmap_repair_speedup": (">=", 3.0, "vmap repair speedup floor"),
        # ... sharding costs at most 5% optimality at n=500 / 8 cells ...
        "cost_ratio_n500": ("<=", 1.05, "sharded cost-parity ceiling"),
        # ... and a single-cell sharded replay is bit-identical to flat.
        "single_cell_cost_delta": ("<=", 0.0, "single-cell bit-identity"),
    },
    "BENCH_solver.json": {
        # Acceptance: branch-and-price must certify <= 1% gap on the
        # n=500 / 10-kind fleet (measured ~0.9%: cost 33.15 vs Farley
        # lower bound 32.87) ...
        "colgen_gap_n500k10": ("<=", 0.01, "colgen certified-gap ceiling"),
        # ... exactly where budgeted pattern enumeration strands >= 5%
        # above the same admissible bound ...
        "arcflow_budget_gap_n500k10": (">=", 0.05, "enumeration gap floor"),
        # ... on the *calibrated* n=500 / 10-kind fleet (stream kinds and
        # requirement vectors from CALIBRATION_ec2.json, regenerable via
        # scripts/recalibrate.py) colgen must also certify <= 1%
        # (measured 0.0%: real program mixes are more structured than the
        # adversarial synthetic kinds) ...
        "colgen_gap_calibrated_n500k10": ("<=", 0.01, "calibrated-fleet colgen gap"),
        # ... the batched pricing dispatch beats the serial per-kind
        # numpy reference loop >= 3x on identical inputs (measured ~6x
        # at 16 nodes x 3 kinds) ...
        "pricing_batched_speedup": (">=", 3.0, "batched pricing speedup floor"),
        # ... and every kernel impl is bit-identical to the reference.
        "pricing_bitident_mismatch": ("<=", 0.0, "kernel bit-equivalence"),
    },
    "BENCH_calibration.json": {
        # Acceptance: the calibrated TPU-cloud mix must exercise the
        # paper's CPU-vs-accelerator multiple-choice dimension — at least
        # one stream lands on each device class (measured 29 cpu / 21
        # accel on the fixed 50-stream mix) ...
        "calibrated_cpu_streams": (">=", 1.0, "CPU choice actually taken"),
        "calibrated_accel_streams": (">=", 1.0, "accel choice actually taken"),
        # ... a 2x faster accelerator profile must lower the certified
        # fleet cost on the identical mix by >= 2% (measured ~3.7%:
        # compute-bound prefill packs denser, memory-bound kinds do not
        # move) ...
        "accel2x_cost_saving": (">=", 0.02, "kernel speedup reaches the bill"),
        # ... the numpy and jax calibration paths (and a repeated run)
        # must agree bit for bit ...
        "calib_bitident_mismatch": ("<=", 0.0, "calibration bit-identity"),
        # ... and the committed CALIBRATION_*.json artifacts must equal a
        # fresh in-process calibration (scripts/recalibrate.py --check).
        "calib_artifact_fresh": (">=", 1.0, "committed artifacts fresh"),
    },
    "BENCH_policy.json": {
        # Acceptance: bounded-migration consolidation (k<=3 per event) must
        # end the 500-stream / 200-event trace >= 5% cheaper than the
        # pure-pinning controller ...
        "consolidation_saving": (">=", 0.05, "consolidation end-of-trace saving"),
        # ... while warm re-plans (policy overhead included) stay >= 5x
        # faster than from-scratch solves of the same fleets ...
        "speedup_warm_vs_cold": (">=", 5.0, "warm-start speedup floor"),
        # ... and no event ever exceeds the k = 3 migration budget.
        "max_migrations_per_event": ("<=", 3.0, "migration budget ceiling"),
    },
}


def check(path: str) -> list[str]:
    name = path.rsplit("/", 1)[-1]
    rules = FLOORS.get(name)
    if rules is None:
        return [f"{name}: no floors registered (add it to FLOORS)"]
    try:
        meta = json.load(open(path))["meta"]
    except (OSError, ValueError, KeyError) as e:
        return [f"{name}: unreadable artifact ({e})"]
    problems = []
    for key, (op, bound, what) in rules.items():
        value = meta.get(key)
        if value is None:
            problems.append(f"{name}: meta[{key!r}] missing ({what})")
            continue
        ok = value >= bound if op == ">=" else value <= bound
        status = "ok" if ok else "FAIL"
        print(f"{name}: {key} = {value:.4g} (need {op} {bound}) {status}")
        if not ok:
            problems.append(f"{name}: {what} violated: {value:.4g} !{op} {bound}")
    return problems


def main() -> None:
    if len(sys.argv) < 2:
        sys.exit(__doc__)
    problems = []
    for path in sys.argv[1:]:
        problems += check(path)
    if problems:
        print("\n".join(problems), file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
