"""Regenerate the committed calibration artifacts (CALIBRATION_*.json).

Usage:
  PYTHONPATH=src python scripts/recalibrate.py            # all presets
  PYTHONPATH=src python scripts/recalibrate.py tpu        # one preset
  PYTHONPATH=src python scripts/recalibrate.py --impl jax # vectorized path
  PYTHONPATH=src python scripts/recalibrate.py --measured # wall-clock CPU runs
  PYTHONPATH=src python scripts/recalibrate.py --check    # freshness gate

This is the regeneration entry point of the calibrated-requirements loop:
when kernels (or hardware constants, or the workload set) change, rerun it
and every calibrated benchmark re-derives its requirement vectors from the
new artifact.  The default analytic mode is deterministic — rerunning
without a source change rewrites byte-identical files, which is what
``--check`` verifies (exit 1 when a committed artifact is stale or
missing).  ``--measured`` swaps in real `measure_cpu_profile` wall-clock
test runs for the runnable vision programs (the paper's actual procedure;
nondeterministic, recorded in provenance).
"""
from __future__ import annotations

import argparse
import sys

from repro.core import calibration as cal


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("presets", nargs="*", default=None,
                    help=f"presets to regenerate (default: all of {sorted(cal.PRESETS)})")
    ap.add_argument("--impl", choices=("numpy", "jax"), default="numpy")
    ap.add_argument("--measured", action="store_true",
                    help="wall-clock CPU test runs instead of analytic")
    ap.add_argument("--check", action="store_true",
                    help="verify committed artifacts match a fresh analytic "
                         "calibration; write nothing")
    args = ap.parse_args()
    names = args.presets or sorted(cal.PRESETS)
    stale = []
    for name in names:
        preset = cal.PRESETS[name]
        artifact = cal.calibrate(
            preset.catalog_fn(),
            preset.workloads_fn(),
            cpu=preset.cpu,
            roofline=preset.roofline,
            impl=args.impl,
            cpu_mode="measured" if args.measured else "analytic",
            host_cores_fraction=preset.host_cores_fraction,
        )
        path = cal.default_artifact_path(name)
        if args.check:
            try:
                on_disk = cal.CalibrationArtifact.load(path)
            except (OSError, ValueError, KeyError):
                on_disk = None
            fresh = on_disk == artifact
            print(f"{path.name}: {'fresh' if fresh else 'STALE'} "
                  f"({len(artifact.entries)} entries, sig {artifact.catalog_signature})")
            if not fresh:
                stale.append(name)
            continue
        artifact.save(path)
        print(f"wrote {path.name}: {len(artifact.entries)} profiles over "
              f"{len(artifact.programs())} programs, catalog sig "
              f"{artifact.catalog_signature}, mode "
              f"{artifact.provenance['cpu_mode']}/{artifact.provenance['impl']}")
        for e in artifact.entries:
            req = ", ".join(f"{x:.4g}" for x in e.requirement)
            print(f"  {e.program_id:24s} {e.device:5s} [{req}] "
                  f"max {e.max_fps:.4g} fps ({e.source})")
    if stale:
        print(f"stale artifacts: {stale} — rerun scripts/recalibrate.py",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
