"""Quickstart: the paper's pipeline in 60 seconds.

1. Profile analysis programs (paper Table 3 profiles).
2. Formulate + exactly solve the multiple-choice vector bin packing.
3. Print the allocation plan and simulated fleet performance.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.binpack import BinType
from repro.core.manager import ResourceManager
from repro.core.profiler import paper_profile_table
from repro.core.simulator import simulate_plan
from repro.core.strategies import ALL_STRATEGIES
from repro.core.streams import AnalysisProgram, StreamSpec


def main() -> None:
    vgg = AnalysisProgram("VGG-16", "vgg16")
    zf = AnalysisProgram("ZF", "zf")

    # Paper scenario 1: one VGG stream at 0.25 FPS, three ZF at 0.55 FPS.
    streams = [StreamSpec("cam-vgg", vgg, 0.25)] + [
        StreamSpec(f"cam-zf{i}", zf, 0.55) for i in range(3)
    ]
    catalog = (
        BinType("c4.2xlarge", (8, 15, 0, 0), 0.419),
        BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650),
    )
    table = paper_profile_table()
    manager = ResourceManager(catalog, table)

    for strategy in ALL_STRATEGIES:
        plan = manager.allocate(streams, strategy)
        sim = simulate_plan(plan, table, target=manager.utilization_cap)
        print(f"\n=== {strategy.name}: {strategy.description}")
        print(plan.summary())
        print(f"simulated performance: {sim['overall_performance']:.0%} "
              f"(target >= 90%: {'OK' if sim['meets_target'] else 'MISS'})")

    st1 = manager.allocate(streams, ALL_STRATEGIES[0]).hourly_cost
    st3 = manager.allocate(streams, ALL_STRATEGIES[2]).hourly_cost
    print(f"\nST3 saves {1 - st3 / st1:.0%} vs ST1 (paper: 61%)")


if __name__ == "__main__":
    main()
