"""Train a small model end-to-end with the full substrate.

Uses the real training stack (data pipeline -> loss -> AdamW -> checkpoint)
on a scaled-down internlm2-family config. Defaults are sized for this
single-core CPU container (~15M params, 60 steps); pass --preset 100m for
the full ~100M-param / 300-step run on real hardware.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 60]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data import BatchSpec, token_batches
from repro.models import transformer as tfm
from repro.train import AdamWConfig, train
from repro.train.checkpoint import save

PRESETS = {
    # ~15M params: fits a laptop/CI CPU.
    "15m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                head_dim=64, d_ff=1024, vocab_size=8192),
    # ~100M params: the paper-scale example for real hardware.
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32000),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="15m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_small")
    args = ap.parse_args()

    base = get_config("internlm2-1.8b")
    cfg = dataclasses.replace(base, name=f"internlm2-{args.preset}-example",
                              **PRESETS[args.preset])
    print(f"config: {cfg.name}  params~{cfg.param_count()/1e6:.1f}M")

    batches = token_batches(cfg, BatchSpec(args.batch, args.seq_len), seed=0)
    state, history = train(
        cfg, batches, steps=args.steps,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps),
        log_every=max(args.steps // 10, 1),
    )
    first, last = history[0], history[-1]
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"({args.steps} steps, {last['elapsed_s']:.1f}s)")
    save(args.ckpt, state["params"], metadata={"config": cfg.name,
                                               "steps": args.steps})
    print(f"checkpoint written to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
