"""Deep-dive into the allocator: multi-GPU bins, solver cross-checks.

Demonstrates paper §3.2's dimension expansion (2 + 2N dims for N-GPU
instances: g2.8xlarge = 4 GPUs -> 10-dim vectors, 1 + N = 5 choices per
stream) and cross-validates all three solver backends on the same fleet.

Run:  PYTHONPATH=src python examples/allocation_demo.py
"""
import numpy as np

from repro.core.binpack import (
    BinType, Choice, Item, Problem,
    first_fit_decreasing, solve, solve_arcflow,
)
from repro.core.catalog import paper_ec2_catalog


def multi_gpu_fleet(n_streams: int = 6) -> Problem:
    catalog = paper_ec2_catalog(include_multi_gpu=True)  # 10-dim space
    items = []
    rng = np.random.RandomState(3)
    for i in range(n_streams):
        cpu_cores = rng.uniform(1.5, 4.0)
        # Choice 0: CPU execution. Choices 1..4: one per GPU slot.
        choices = [Choice("cpu", (cpu_cores, 0.6) + (0.0,) * 8)]
        for gpu in range(4):
            acc = [0.0] * 8
            acc[2 * gpu] = rng.uniform(80, 250)  # GPU cores
            acc[2 * gpu + 1] = rng.uniform(0.2, 0.5)  # GPU memory
            choices.append(Choice(f"gpu{gpu}", (cpu_cores * 0.14, 0.6, *acc)))
        items.append(Item(f"s{i}", tuple(choices)))
    return Problem(bin_types=catalog, items=tuple(items), utilization_cap=0.9)


def main() -> None:
    problem = multi_gpu_fleet()
    print(f"fleet: {len(problem.items)} streams, "
          f"{len(problem.items[0].choices)} choices each "
          f"(1 CPU + 4 GPU slots), dim={problem.dim}")

    exact, stats = solve(problem)
    print(f"\nbin-completion exact: ${exact.cost:.3f} "
          f"({stats.nodes} nodes, optimal={stats.optimal})")
    for i, b in enumerate(exact.bins):
        util = np.asarray(b.load) / np.asarray(b.bin_type.capacity).clip(1e-9)
        members = [
            (problem.items[a.item_index].name,
             problem.items[a.item_index].choices[a.choice_index].label)
            for a in exact.assignments if a.bin_index == i
        ]
        print(f"  [{i}] {b.bin_type.name}: {members} "
              f"max_util={np.nanmax(util):.0%}")

    af, af_stats = solve_arcflow(problem)
    print(f"arc-flow DP:          ${af.cost:.3f} "
          f"({af_stats.n_patterns} patterns, {af_stats.n_classes} classes)")
    ffd = first_fit_decreasing(problem)
    print(f"FFD heuristic:        ${ffd.cost:.3f} "
          f"(+{(ffd.cost / exact.cost - 1):.0%} vs exact)")
    assert abs(af.cost - exact.cost) < 1e-6, "solvers disagree!"
    print("\nsolvers agree on the optimum — multi-GPU dimension expansion OK")


if __name__ == "__main__":
    main()
