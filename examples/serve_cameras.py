"""End-to-end driver: manager-planned fleet actually SERVING requests.

The full loop of the paper, data plane included:

  1. A fleet of "camera" streams wants analysis by transformer models
     (the 2026 analysis programs) at given request rates.
  2. The ResourceManager profiles, formulates MC-VBP, and solves for the
     cheapest instance fleet (TPU-cloud catalog).
  3. Each planned instance boots a ServingEngine (smoke-scale weights so
     this runs on the CPU container) and serves its assigned streams'
     batched requests; we report generated tokens, hourly cost, and
     simulated utilization.

Run:  PYTHONPATH=src python examples/serve_cameras.py [--requests 3]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.catalog import tpu_cloud_catalog
from repro.core.manager import ResourceManager
from repro.core.profiler import ProfileTable, ResourceProfile, TPU_V5E
from repro.core.simulator import simulate_plan
from repro.core.streams import AnalysisProgram, FrameSize, StreamSpec
from repro.models import transformer as tfm
from repro.roofline.analysis import model_flops
from repro.serving import Request, ServingEngine

ARCHS = ("internlm2-1.8b", "gemma2-2b")


def build_profiles() -> ProfileTable:
    table = ProfileTable()
    for arch in ARCHS:
        cfg = get_config(arch)
        flops_tok = model_flops(cfg, 1) * 1.15
        mem_gb = cfg.param_count() * 2 / 1e9 + 2.0
        cores_per_tok = flops_tok / 75e9
        table.add(ResourceProfile(arch, "0x0", "cpu", 1.0,
                                  (cores_per_tok, mem_gb, 0, 0),
                                  max_fps=16.0 / cores_per_tok))
        occ = TPU_V5E.occupancy_per_frame(flops_tok, cfg.param_count() * 2)
        table.add(ResourceProfile(arch, "0x0", "accel", 1.0,
                                  (cores_per_tok * 0.05, mem_gb * 0.25,
                                   occ * 197.0, mem_gb),
                                  max_fps=1.0 / occ))
    return table


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    streams = [
        StreamSpec("traffic-cam", AnalysisProgram("a", "internlm2-1.8b"), 25.0,
                   FrameSize(0, 0)),
        StreamSpec("mall-cam", AnalysisProgram("b", "internlm2-1.8b"), 10.0,
                   FrameSize(0, 0)),
        StreamSpec("river-cam", AnalysisProgram("c", "gemma2-2b"), 6.0,
                   FrameSize(0, 0)),
    ]
    table = build_profiles()
    manager = ResourceManager(tpu_cloud_catalog(), table)
    plan = manager.allocate(streams)
    print("=== allocation plan (exact MC-VBP solve)")
    print(plan.summary())
    sim = simulate_plan(plan, table, target=manager.utilization_cap)
    print(f"simulated fleet performance: {sim['overall_performance']:.0%}\n")

    # Boot one engine per planned instance and serve its streams' requests.
    key = jax.random.PRNGKey(0)
    rid = 0
    for inst_i, inst_type in enumerate(plan.instances):
        members = [p for p in plan.placements if p.instance_index == inst_i]
        archs = {p.stream.program.program_id for p in members}
        print(f"--- instance [{inst_i}] {inst_type} hosts "
              f"{[p.stream.name for p in members]}")
        for arch in sorted(archs):
            cfg = smoke_variant(get_config(arch))  # smoke weights on CPU
            params = tfm.init_params(key, cfg)
            engine = ServingEngine(cfg, params, batch_slots=2, max_seq=64)
            n_streams = sum(
                1 for p in members if p.stream.program.program_id == arch)
            for _ in range(args.requests * n_streams):
                prompt = np.arange(6 + rid % 4) % cfg.vocab_size
                engine.submit(Request(rid=rid, prompt=prompt,
                                      max_new_tokens=args.new_tokens))
                rid += 1
            results = engine.run()
            toks = sum(len(r.tokens) for r in results)
            print(f"    {arch}: served {len(results)} requests, "
                  f"{toks} tokens generated")
    print(f"\nhourly cost: ${plan.hourly_cost:.2f} "
          f"(optimal={plan.optimal})")


if __name__ == "__main__":
    main()
