"""Benchmark harness: one module per paper table/figure + beyond-paper studies.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.record).

  table2_speedup     — paper Table 2 (CPU vs accelerator max frame rates)
  table3_requirements— paper Table 3 (requirement vectors @ 0.2 FPS)
  fig5_framerate     — paper Fig. 5 (linearity + performance knee vs FPS)
  fig6_streams       — paper Fig. 6 (linearity + knee vs #streams)
  table6_strategies  — paper Table 6 (ST1/ST2/ST3 costs, 61/36/3% savings)
  solver_scaling     — beyond-paper solver study (exact vs arc-flow vs FFD)
  tpu_allocation     — beyond-paper TPU-cloud allocation scenario
  churn_replan       — live-churn warm-start re-planning vs from-scratch
  consolidation      — policy layer: bounded-migration consolidation vs pinning
  lifecycle          — instance lifecycle & billing: quantized billing,
                       acting autoscaler vs reactive, billing-aware moves
  spot               — spot/preemptible market: risk-aware vs naive spot vs
                       all-on-demand on a preemption-heavy trace
  storm              — fault-injection storms: SLA tiers, graceful frame-rate
                       degradation, interruption-notice draining
  calibration        — profile-calibrated requirements: artifact freshness
                       + impl bit-identity, calibrated CPU-vs-accelerator
                       multiple-choice allocation, and the kernel→dollars
                       probe (2× faster accel profile must cut fleet cost)
  shard              — hierarchical sharded controller: 100k-stream replay
                       through the batched event pipeline (vs the serial
                       per-event loop, bit-identity gated), one-dispatch
                       certification, vmapped per-cell batched repair,
                       flat-infeasibility probe, cost parity vs flat
  roofline_report    — §Roofline table from dry-run artifacts

Suites that emit a gated artifact (``churn_replan`` → ``BENCH_replan.json``,
``consolidation`` → ``BENCH_policy.json``) are checked against their stored
regression floors by ``scripts/check_bench.py`` after they run; a floor
violation fails the harness like any suite error.
"""
import argparse
import pathlib
import subprocess
import sys
import time
import traceback

#: suite name -> artifact its run() emits, gated by scripts/check_bench.py.
GATED_ARTIFACTS = {
    "calibration": "BENCH_calibration.json",
    "churn": "BENCH_replan.json",
    "policy": "BENCH_policy.json",
    "lifecycle": "BENCH_lifecycle.json",
    "spot": "BENCH_spot.json",
    "storm": "BENCH_storm.json",
    "shard": "BENCH_shard.json",
    "solver": "BENCH_solver.json",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument(
        "--no-gate", action="store_true",
        help="skip the check_bench.py regression floors",
    )
    args = ap.parse_args()

    from . import (
        ablation_cap,
        calibration,
        churn_replan,
        consolidation,
        fig5_framerate,
        fig6_streams,
        lifecycle,
        roofline_report,
        shard,
        solver_scaling,
        spot,
        storms,
        table2_speedup,
        table3_requirements,
        table6_strategies,
        tpu_allocation,
    )

    suites = {
        "table6": table6_strategies,
        "fig5": fig5_framerate,
        "fig6": fig6_streams,
        "table3": table3_requirements,
        "table2": table2_speedup,
        "solver": solver_scaling,
        "tpu": tpu_allocation,
        "ablation": ablation_cap,
        "calibration": calibration,
        "churn": churn_replan,
        "policy": consolidation,
        "lifecycle": lifecycle,
        "spot": spot,
        "storm": storms,
        "shard": shard,
        "roofline": roofline_report,
    }
    selected = args.only or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        t0 = time.perf_counter()
        try:
            suites[name].run()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            continue
        finally:
            wall = time.perf_counter() - t0
            print(f"[wall] {name}: {wall:.1f}s", file=sys.stderr)
        artifact = GATED_ARTIFACTS.get(name)
        if artifact and not args.no_gate:
            gate = pathlib.Path(__file__).parent.parent / "scripts" / "check_bench.py"
            proc = subprocess.run([sys.executable, str(gate), artifact])
            if proc.returncode != 0:
                failed.append(f"{name} (regression gate)")
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
