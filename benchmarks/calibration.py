"""Beyond-paper: calibrated requirements — kernel throughput to fleet dollars.

End-to-end demonstration of the profile-calibrated requirement path
(`core.calibration`): every requirement vector the allocator packs here
comes from a measured/derived profile (`measure_cpu_profile` analytics +
`derive_accelerator_profile` roofline terms over the compiled configs) —
no hand-written numbers anywhere on the path.

Four probes, all against the committed ``CALIBRATION_*.json`` artifacts
(regenerable via ``scripts/recalibrate.py``):

* **freshness** — the committed artifacts must equal an in-process
  re-calibration bit for bit (the determinism contract `recalibrate.py
  --check` enforces at the CLI);
* **bit-identity** — the vectorized jax float64 path must produce the
  exact same artifact as the per-entry numpy path, and a repeated run
  the same again (quantized float64 all the way down);
* **multiple-choice allocation** — a fixed 50-stream TPU-cloud mix
  (vision nets + LLM frame analyzers at spread rates) must split across
  *both* device classes: CPU hosts win the low-rate/small-model streams,
  accelerators the deep-context/high-rate ones — the paper's CPU-vs-GPU
  choice dimension, now driven by calibrated vectors;
* **kernel→dollars** — `with_accelerator_speedup(2.0)` (a 2× faster
  accelerator profile: peak FLOPS and HBM bandwidth doubled, host cores
  and memory untouched) re-derives the artifact, and
  `FleetController.recalibrate` re-plans the identical mix: the
  certified fleet cost must drop ≥ 2% (measured ~3.7%) because the
  accel-compute-bound streams now pack denser.  Memory-bound kinds do
  not move — the saving isolates exactly the compute the speedup bought.

Gated via ``BENCH_calibration.json`` (`scripts/check_bench.py`).
"""
from __future__ import annotations

import time

from repro.core import calibration as cal
from repro.core.catalog import paper_ec2_catalog, tpu_cloud_catalog
from repro.core.manager import ResourceManager
from repro.core.streams import AnalysisProgram, StreamSpec

from .common import record, write_json

#: The fixed TPU-cloud mix: (program, fps, count).  Rates are spread so
#: the calibrated max-fps economics put some kinds on CPU hosts (vision
#: at trickle rates, small LLMs at deep-audit rates) and some on
#: accelerators (deep-context prefill at interactive rates) — every rate
#: is feasible per the artifact (`check_stream` enforces it at build).
MIX = (
    ("vgg16", 0.2, 12),
    ("zf", 5.0, 8),
    ("internlm2-1.8b", 0.05, 10),
    ("gemma2-2b", 4.0, 8),
    ("llava-next-mistral-7b", 1.5, 6),
    ("mamba2-1.3b", 0.4, 6),
)
SPEEDUP = 2.0


def _mix(artifact) -> list[StreamSpec]:
    specs = []
    for pid, fps, n in MIX:
        prog = AnalysisProgram(pid, pid)
        for i in range(n):
            s = StreamSpec(f"{pid[:5]}{i}", prog, fps)
            artifact.check_stream(s)
            specs.append(s)
    return specs


def _device_split(plan) -> dict[str, int]:
    split: dict[str, int] = {}
    for p in plan.placements:
        split[p.device] = split.get(p.device, 0) + 1
    return split


def _entry_delta(a, b) -> float:
    """Max abs difference over paired entries' vectors and max rates."""
    worst = 0.0
    ea = {(e.program_id, e.device): e for e in a.entries}
    eb = {(e.program_id, e.device): e for e in b.entries}
    if set(ea) != set(eb):
        return float("inf")
    for k, x in ea.items():
        y = eb[k]
        worst = max(
            worst,
            max(abs(p - q) for p, q in zip(x.requirement, y.requirement)),
            abs(x.max_fps - y.max_fps),
        )
    return worst


def _freshness_and_bitident() -> dict:
    """Committed artifacts vs fresh calibration; numpy vs jax vs rerun."""
    fresh = 1.0
    mismatch = 0.0
    for name, preset in sorted(cal.PRESETS.items()):
        kwargs = dict(
            cpu=preset.cpu,
            roofline=preset.roofline,
            host_cores_fraction=preset.host_cores_fraction,
        )
        catalog = preset.catalog_fn()
        workloads = preset.workloads_fn()
        t0 = time.perf_counter()
        np_art = cal.calibrate(catalog, workloads, impl="numpy", **kwargs)
        t_np = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        jx_art = cal.calibrate(catalog, workloads, impl="jax", **kwargs)
        t_jx = (time.perf_counter() - t0) * 1e6
        rerun = cal.calibrate(catalog, workloads, impl="numpy", **kwargs)
        try:
            on_disk = cal.CalibrationArtifact.load(cal.default_artifact_path(name))
        except (OSError, ValueError, KeyError):
            on_disk = None
        if on_disk != np_art:
            fresh = 0.0
        # impl bit-identity is over the *entries* (provenance records the
        # impl that produced them, so whole-artifact equality can't hold).
        if np_art.entries != jx_art.entries or np_art != rerun:
            mismatch = max(
                mismatch,
                _entry_delta(np_art, jx_art),
                _entry_delta(np_art, rerun),
            )
        record(
            f"calibration/{name}/calibrate_numpy", t_np,
            f"{len(np_art.entries)} profiles / {len(np_art.programs())} "
            f"programs, sig {np_art.catalog_signature} "
            f"fresh_on_disk={on_disk == np_art}",
        )
        record(
            f"calibration/{name}/calibrate_jax", t_jx,
            f"one vectorized float64 dispatch, "
            f"entries_bitident={np_art.entries == jx_art.entries}",
        )
    return {"calib_artifact_fresh": fresh, "calib_bitident_mismatch": mismatch}


def _ec2_choice_row() -> None:
    """The paper's own scenario on calibrated vectors: c4 vs g2.2xlarge."""
    art = cal.load_or_calibrate("ec2")
    mgr = ResourceManager(paper_ec2_catalog(), calibration=art, solver="colgen")
    streams = []
    for i in range(20):
        streams.append(StreamSpec(f"v{i}", AnalysisProgram("vgg16", "vgg16"), 0.2))
    for i in range(20):
        streams.append(StreamSpec(f"z{i}", AnalysisProgram("zf", "zf"), 5.0))
    t0 = time.perf_counter()
    plan = mgr.allocate(streams)
    dt = (time.perf_counter() - t0) * 1e6
    record(
        "calibration/ec2/allocate", dt,
        f"cost=${plan.hourly_cost:.3f} split={_device_split(plan)} "
        f"instances={plan.instance_counts()}",
    )


def run() -> dict:
    out = _freshness_and_bitident()
    _ec2_choice_row()

    art = cal.load_or_calibrate("tpu")
    catalog = tpu_cloud_catalog()
    streams = _mix(art)
    mgr = ResourceManager(catalog, calibration=art, solver="colgen")
    t0 = time.perf_counter()
    plan = mgr.allocate(streams)
    t_alloc = (time.perf_counter() - t0) * 1e6
    split = _device_split(plan)
    record(
        "calibration/tpu/allocate_mix", t_alloc,
        f"cost=${plan.hourly_cost:.3f} split={split} "
        f"instances={plan.instance_counts()} n={len(streams)}",
    )

    # Kernel→dollars: a 2× faster accelerator profile, same catalog, same
    # streams, re-planned through the controller's recalibrate path.
    ctrl = mgr.controller()
    fast = art.with_accelerator_speedup(SPEEDUP)
    t0 = time.perf_counter()
    r = ctrl.recalibrate(fast)
    t_recal = (time.perf_counter() - t0) * 1e6
    saving = 1.0 - r.plan.hourly_cost / plan.hourly_cost
    record(
        "calibration/tpu/recalibrate_2x", t_recal,
        f"cost=${plan.hourly_cost:.3f} -> ${r.plan.hourly_cost:.3f} "
        f"({saving:.1%} saving) split={_device_split(r.plan)} "
        f"instances={r.plan.instance_counts()}",
    )

    out.update(
        calibrated_cpu_streams=float(split.get("cpu", 0)),
        calibrated_accel_streams=float(split.get("accel", 0)),
        calibrated_mix_cost=plan.hourly_cost,
        calibrated_mix_cost_2x=r.plan.hourly_cost,
        accel2x_cost_saving=saving,
    )
    record(
        "calibration/summary", 0.0,
        f"cpu={split.get('cpu', 0)} accel={split.get('accel', 0)} "
        f"2x_saving={saving:.1%} bitident_mismatch="
        f"{out['calib_bitident_mismatch']:.1g} "
        f"fresh={out['calib_artifact_fresh']:.0f}",
    )
    write_json(
        "BENCH_calibration.json",
        prefix="calibration/",
        meta={
            "n_streams": float(len(streams)),
            "accelerator_speedup": SPEEDUP,
            **out,
        },
    )
    return out
