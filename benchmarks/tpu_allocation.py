"""Beyond-paper: the manager allocating LLM-serving streams on a TPU cloud.

The 2026 version of the paper's scenario: "analysis programs" are the
assigned transformer architectures serving token streams at desired
request rates; requirement vectors are derived from the dry-run roofline
(artifacts if present, else the analytic model); the catalog offers CPU
hosts and v5e slices. ST3's mixed fleets beat accelerator-only (ST2) and
CPU-only (ST1) exactly as in paper Table 6.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.catalog import tpu_cloud_catalog
from repro.core.manager import ResourceManager
from repro.core.profiler import ProfileTable, ResourceProfile, TPU_V5E
from repro.core.strategies import ALL_STRATEGIES
from repro.core.streams import AnalysisProgram, StreamSpec
from repro.core.binpack import InfeasibleError
from repro.configs import get_config
from repro.roofline.analysis import model_flops

from .common import record

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                            "dryrun")

#: Small serving archs a single host/slice can plausibly hold.
SERVE_ARCHS = ("internlm2-1.8b", "gemma2-2b", "mamba2-1.3b")


def _artifact_flops(arch: str) -> float | None:
    path = os.path.join(ARTIFACT_DIR, f"{arch}__decode_32k__16x16.json")
    if not os.path.exists(path):
        return None
    rec = json.load(open(path))
    return rec["hlo_flops"] / rec["batch"]  # FLOPs per generated token


def build_profiles() -> ProfileTable:
    """Per arch: requirement vectors per generated token/s ("frame rate" =
    tokens/s here), CPU from a throughput model, accel from the roofline."""
    table = ProfileTable()
    for arch in SERVE_ARCHS:
        cfg = get_config(arch)
        flops_tok = _artifact_flops(arch) or model_flops(cfg, 1) * 1.15
        mem_gb = cfg.param_count() * 2 / 1e9 + 2.0  # weights + cache/overhead
        # CPU host: ~75 GFLOP/s effective per core for bf16 GEMMs.
        cores_per_tok_s = flops_tok / 75e9
        table.add(ResourceProfile(
            program_id=arch, frame_size="0x0", device="cpu",
            reference_fps=1.0,
            requirement=(cores_per_tok_s, mem_gb, 0.0, 0.0),
            max_fps=16.0 / cores_per_tok_s,
        ))
        occ = TPU_V5E.occupancy_per_frame(flops_tok, cfg.param_count() * 2)
        table.add(ResourceProfile(
            program_id=arch, frame_size="0x0", device="accel",
            reference_fps=1.0,
            requirement=(cores_per_tok_s * 0.05, mem_gb * 0.25,
                         occ * TPU_V5E.compute_capacity_units, mem_gb),
            max_fps=1.0 / occ,
        ))
    return table


def run() -> dict:
    from repro.core.streams import FrameSize  # noqa: F401

    table = build_profiles()
    catalog = tpu_cloud_catalog()
    mgr = ResourceManager(catalog, table)

    # Fleet: a mixed serving workload (rates in tokens/s per stream).
    fleet = []
    for i in range(4):
        fleet.append(_stream(f"chat{i}", "internlm2-1.8b", 30.0))
    for i in range(2):
        fleet.append(_stream(f"cam{i}", "gemma2-2b", 8.0))
    fleet.append(_stream("log0", "mamba2-1.3b", 2.0))

    out = {}
    for strat in ALL_STRATEGIES:
        try:
            plan = mgr.allocate(fleet, strat)
            out[strat.name] = plan.hourly_cost
            record(
                f"tpu_alloc/{strat.name}", 0.0,
                f"cost=${plan.hourly_cost:.2f}/h "
                f"instances={plan.instance_counts()}",
            )
        except InfeasibleError as e:
            out[strat.name] = None
            record(f"tpu_alloc/{strat.name}", 0.0, f"FAIL({e})")
    if out.get("ST3") and out.get("ST2"):
        record("tpu_alloc/savings", 0.0,
               f"st3_vs_st2={1 - out['ST3'] / out['ST2']:.0%}")
    return out


def _stream(name: str, arch: str, rate: float) -> StreamSpec:
    from repro.core.streams import FrameSize

    return StreamSpec(
        name=name, program=AnalysisProgram(arch, arch), desired_fps=rate,
        frame_size=FrameSize(0, 0),
    )
