"""§Roofline report: render the per-(arch x shape) table from dry-run
artifacts (artifacts/dryrun/*.json, produced by repro.launch.dryrun)."""
from __future__ import annotations

import glob
import json
import os

from .common import record

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                            "dryrun")
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load_records(mesh: str = "16x16") -> list[dict]:
    recs = []
    for path in glob.glob(os.path.join(ARTIFACT_DIR, f"*__{mesh}.json")):
        recs.append(json.load(open(path)))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return recs


def run() -> dict:
    recs = load_records()
    if not recs:
        record("roofline/none", 0.0, "no dry-run artifacts yet")
        return {}
    for r in recs:
        t = r["roofline"]
        record(
            f"roofline/{r['arch']}/{r['shape']}",
            max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6,
            f"t_comp={t['compute_s']*1e3:.2f}ms t_mem={t['memory_s']*1e3:.2f}ms "
            f"t_coll={t['collective_s']*1e3:.2f}ms dom={t['dominant']} "
            f"useful_frac={r['useful_flops_frac']:.2f}"
            if r["useful_flops_frac"] else
            f"t_comp={t['compute_s']*1e3:.2f}ms t_mem={t['memory_s']*1e3:.2f}ms "
            f"t_coll={t['collective_s']*1e3:.2f}ms dom={t['dominant']}",
        )
    return {"n": len(recs)}
