"""Beyond-paper: fault-injection storm study — SLA tiers under fire.

Replays ONE seeded correlated-failure storm (`streams.storm_trace`: a
background churn trace plus waves of interruption notices with paired
kills, no-warning reclaims, a flash crowd, a price spike, and false
alarms) over a tiered 120-stream fleet (20% GOLD / 30% SILVER / 50%
BRONZE — `streams.SLATier`) on the PR-5 two-tier spot market, through
three controllers that differ only in robustness posture:

* **pr5_risk** — the PR-5 baseline: risk-adjusted catalog +
  `PinningPolicy`, interruption notices ignored (``drain_on_notice
  = False``).  Every kill lands cold: the victims' streams black out
  for a replacement boot.
* **notice_drain** — same policy, ``drain_on_notice=True``: the
  controller evacuates noticed instances inside the warning window
  (make-before-break against the clock), converting notice-paired kills
  into ordinary double-billed migrations.  No-warning reclaims still
  black out.
* **tiered** — notice draining plus `GracefulDegradationPolicy`: when
  storm repair lands streams on cold capacity, low-rank tiers step down
  their frame-rate ladder (requirement vectors shrink — lower fps only
  *gains* device choices under the paper profiles), and the freed warm
  residual lets the mechanism re-home the stranded victims immediately;
  calm events restore rungs.  Parking is disabled (this fleet has
  headroom to boot replacements, so parking would only add blackout).

All three replay the *identical* pre-generated trace — notice/kill
pairs share ``notice_id`` so both resolve to the same instance no
matter what the policy did in between.

Gated via ``BENCH_storm.json`` (`scripts/check_bench.py`): the tiered
run must end with zero GOLD SLA violations; notice draining must cut
total blackout stream-seconds >= 60% vs the pr5_risk baseline at <= 10%
billed-cost overhead; >= 80% of victim-bearing notice steps must drain
tail-free; and the tiered run's utility penalty (rung-hours priced at
each tier's ``rung_penalty`` + blackout at ``blackout_penalty``) must
stay below the baseline's pure-blackout penalty.

PR 10 adds the cost-vs-QoS curve (``storm/qos/*`` rows): the tiered
posture replayed at swept utility-price multipliers (`QOS_SCALES`),
tracing how the billed-cost / utility-penalty pair moves as lost
quality gets cheaper or dearer relative to instance-hours.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.lifecycle import BillingModel
from repro.core.manager import ResourceManager
from repro.core.policy import (
    GracefulDegradationPolicy,
    PinningPolicy,
    risk_adjusted_catalog,
)
from repro.core.profiler import paper_profile_table
from repro.core.simulator import simulate_churn
from repro.core.streams import (
    BRONZE,
    GOLD,
    SILVER,
    InstancePreempted,
    InstancePreemptionNotice,
    StormPhase,
    StreamSpec,
    storm_trace,
)

from . import consolidation, spot
from .common import record, write_json

N_STREAMS = 120
N_BACKGROUND = 30
MEAN_GAP_H = 0.02
NOTICE_H = 2.5 / 60.0  # warning window: covers the 2-minute boot
HAZARD_POOL = 48  # notice/reclaim slots: >= max concurrent spot instances
#: Storm posture: warm incremental repair only.  A degraded fleet mixes
#: fractional rates into many small item classes — the worst case for the
#: exact pattern solvers — and the dual lower bound goes loose mid-storm
#: (observed warm gaps ~1.3), so a tight threshold would trigger
#: minute-long global re-solves exactly when the controller must be fast.
#: Global re-certification is a calm-time activity; all three replays
#: share the setting, so the comparison stays apples-to-apples.
GAP_THRESHOLD = 10.0
SEED = 8231

#: Deterministic 20/30/50 tier mix by stream index.
TIER_WHEEL = (GOLD, GOLD, SILVER, SILVER, SILVER) + (BRONZE,) * 5


def _tier(i: int, wheel=TIER_WHEEL):
    return wheel[i % len(wheel)]


def _initial_fleet(wheel=TIER_WHEEL) -> list[StreamSpec]:
    kinds = consolidation.KINDS
    return [
        StreamSpec(f"s{i}", *kinds[i % len(kinds)], tier=_tier(i, wheel))
        for i in range(N_STREAMS)
    ]


def _phases() -> list[StormPhase]:
    spike = "c4.2xlarge-spot-stable"
    return [
        # The correlated wave real clouds deliver: many notices at once.
        StormPhase("notice", at=0.5, count=10, notice_hours=NOTICE_H),
        StormPhase("flash_crowd", at=0.9, count=12),
        StormPhase("price", at=1.2, instance_type=spike, cost=0.60),
        StormPhase("price", at=1.5, instance_type=spike, cost=0.189),
        StormPhase("reclaim", at=1.6, count=8),  # no warning at all
        StormPhase("false_alarm", at=2.0, count=3),
    ]


def _trace(initial, wheel=TIER_WHEEL):
    rng = np.random.RandomState(SEED)
    kinds = consolidation.KINDS

    def make_join(i):
        return StreamSpec(f"g{i}", *kinds[i % len(kinds)], tier=_tier(i, wheel))

    return storm_trace(
        initial,
        rng,
        phases=_phases(),
        n_background=N_BACKGROUND,
        mean_gap_hours=MEAN_GAP_H,
        p_join=0.35,
        p_leave=0.25,
        make_join=make_join,
        rerate_fps=lambda s: [
            fps
            for prog, fps in kinds
            if prog.program_id == s.program.program_id
        ],
        hazard_pool=HAZARD_POOL,
    )


def _replay(catalog, initial, trace, by_type, *, policy, drain):
    mgr = ResourceManager(
        catalog, paper_profile_table(), max_nodes=consolidation.MAX_NODES
    )
    mgr.controller(gap_threshold=GAP_THRESHOLD)
    return simulate_churn(
        mgr,
        initial,
        trace,
        paper_profile_table(),
        policy=policy,
        billing=spot.HOURLY,
        billing_by_type=by_type,
        drain_on_notice=drain,
    )


#: Utility-price multipliers for the cost-vs-QoS curve.  1.0 is the
#: headline tiered run (reused, not re-replayed); the sweep reprices
#: every tier's ``rung_penalty`` / ``blackout_penalty`` and the risk
#: catalog's degraded-capacity penalty by the same factor, then replays
#: the identical storm.  Up to 4x the tiered posture's *decisions* are
#: price-insensitive (same $16.6 bill, penalty scales linearly); at 16x
#: the risk-adjusted catalog prices flaky spot out entirely and the
#: fleet buys reliable capacity (~3.4x the bill, zero blackout, zero
#: penalty) — the two regimes ARE the cost-vs-QoS tradeoff.
QOS_SCALES = (0.25, 1.0, 4.0, 16.0)


def _scaled_wheel(scale: float):
    return tuple(
        dataclasses.replace(
            t,
            rung_penalty=t.rung_penalty * scale,
            blackout_penalty=t.blackout_penalty * scale,
        )
        for t in TIER_WHEEL
    )


def _qos_sweep(spot_cat, by_type, tiered_out) -> dict:
    """Cost-vs-QoS frontier: replay the storm at swept utility prices.

    Same seeded storm, same tiered controller posture; only the price of
    lost quality moves.  Cheap penalties let the risk-adjusted catalog
    ride flaky capacity (lower bill, more accrued penalty); expensive
    penalties push it onto reliable instances and make degradation
    costly relative to the bill.  The emitted ``storm/qos/*`` rows are
    the curve; `scripts/perf_diff.py` diffs the per-point pairs.
    """
    points = []
    for scale in QOS_SCALES:
        if scale == 1.0:
            out = tiered_out  # the headline tiered run, verbatim
            dt_us = 0.0
        else:
            wheel = _scaled_wheel(scale)
            initial = _initial_fleet(wheel)
            trace = _trace(initial, wheel)
            cat = risk_adjusted_catalog(
                spot_cat,
                spot.HOURLY,
                billing_by_type=by_type,
                degraded_penalty=spot.DEGRADED_PENALTY * scale,
            )
            t0 = time.perf_counter()
            out = _replay(
                cat, initial, trace, by_type,
                policy=GracefulDegradationPolicy(park_stranded=False),
                drain=True,
            )
            dt_us = (time.perf_counter() - t0) * 1e6
        points.append((scale, out["billed_cost"], out["utility_penalty"]))
        record(
            f"storm/qos/scale_{scale:g}", dt_us,
            f"billed=${out['billed_cost']:.2f} "
            f"utility_penalty={out['utility_penalty']:.1f} "
            f"total=${out['billed_cost'] + out['utility_penalty']:.2f} "
            f"blackout={out['blackout_stream_seconds']:.0f}s "
            f"gold_violations={out['sla'].get('GOLD', {}).get('violations', 0)}",
        )
    return {
        "qos_curve_points": float(len(points)),
        "qos_billed_scale_min": points[0][1],
        "qos_penalty_scale_min": points[0][2],
        "qos_billed_scale_max": points[-1][1],
        "qos_penalty_scale_max": points[-1][2],
    }


def _notice_conversion(out) -> tuple[float, int]:
    """(fraction of victim-bearing notice steps with zero drain tail,
    number of victim-bearing notice steps)."""
    steps = [
        t
        for t in out["timeline"]
        if t["event"] == "InstancePreemptionNotice" and t["notice_victims"]
    ]
    if not steps:
        return 1.0, 0
    clean = sum(t["notice_tail_stream_hours"] <= 1e-9 for t in steps)
    return clean / len(steps), len(steps)


def run() -> dict:
    _, spot_cat, by_type = spot._market()
    risk_cat = risk_adjusted_catalog(
        spot_cat,
        spot.HOURLY,
        billing_by_type=by_type,
        degraded_penalty=spot.DEGRADED_PENALTY,
    )
    initial = _initial_fleet()
    trace = _trace(initial)
    notices = sum(isinstance(ev, InstancePreemptionNotice) for ev in trace)
    kills = sum(isinstance(ev, InstancePreempted) for ev in trace)

    runs = {}
    for name, policy, drain in (
        ("pr5_risk", PinningPolicy(), False),
        ("notice_drain", PinningPolicy(), True),
        # park_stranded=False: with headroom to boot replacements, parking
        # (full blackout while parked, plus a second boot on unpark) is
        # strictly worse than riding out one boot — degrade-and-rehome is
        # the winning move here.  Parking earns its keep only when
        # max_nodes is tight enough that victims cannot re-boot at all.
        ("tiered", GracefulDegradationPolicy(park_stranded=False), True),
    ):
        t0 = time.perf_counter()
        out = _replay(risk_cat, initial, trace, by_type, policy=policy, drain=drain)
        dt = time.perf_counter() - t0
        runs[name] = out
        record(
            f"storm/{name}", dt * 1e6,
            f"billed=${out['billed_cost']:.2f} "
            f"blackout={out['blackout_stream_seconds']:.0f}s "
            f"utility_penalty={out['utility_penalty']:.1f} "
            f"violations={out['sla_violations']} "
            f"gold_violations={out['sla'].get('GOLD', {}).get('violations', 0)}",
        )

    base, drainr, tiered = runs["pr5_risk"], runs["notice_drain"], runs["tiered"]
    blackout_drop = 1.0 - tiered["blackout_stream_seconds"] / max(
        base["blackout_stream_seconds"], 1e-12
    )
    drain_blackout_drop = 1.0 - drainr["blackout_stream_seconds"] / max(
        base["blackout_stream_seconds"], 1e-12
    )
    billed_overhead = tiered["billed_cost"] / base["billed_cost"] - 1.0
    conversion, victim_steps = _notice_conversion(drainr)
    utility_ratio = tiered["utility_penalty"] / max(
        base["utility_penalty"], 1e-12
    )

    out = {
        "blackout_seconds_pr5_risk": base["blackout_stream_seconds"],
        "blackout_seconds_notice_drain": drainr["blackout_stream_seconds"],
        "blackout_seconds_tiered": tiered["blackout_stream_seconds"],
        "blackout_drop_vs_baseline": blackout_drop,
        "drain_blackout_drop_vs_baseline": drain_blackout_drop,
        "billed_cost_pr5_risk": base["billed_cost"],
        "billed_cost_notice_drain": drainr["billed_cost"],
        "billed_cost_tiered": tiered["billed_cost"],
        "tiered_billed_overhead": billed_overhead,
        "gold_violations_tiered": tiered["sla"]
        .get("GOLD", {})
        .get("violations", 0),
        "sla_violations_pr5_risk": base["sla_violations"],
        "sla_violations_tiered": tiered["sla_violations"],
        "utility_penalty_pr5_risk": base["utility_penalty"],
        "utility_penalty_tiered": tiered["utility_penalty"],
        "utility_penalty_ratio": utility_ratio,
        "notice_conversion": conversion,
        "notice_victim_steps": victim_steps,
        "trace_notices": notices,
        "trace_kills": kills,
    }
    out.update(_qos_sweep(spot_cat, by_type, tiered))
    record(
        "storm/summary", 0.0,
        f"blackout {base['blackout_stream_seconds']:.0f}s -> "
        f"{tiered['blackout_stream_seconds']:.0f}s ({blackout_drop:.0%} drop) "
        f"@{billed_overhead:+.2%} billed; conversion={conversion:.0%} "
        f"({victim_steps} notice steps) utility_ratio={utility_ratio:.2f}",
    )
    write_json(
        "BENCH_storm.json",
        prefix="storm/",
        meta={
            "n_streams": N_STREAMS,
            "n_background_events": N_BACKGROUND,
            "hazard_pool": HAZARD_POOL,
            "notice_hours": NOTICE_H,
            "seed": SEED,
            **out,
        },
    )
    return out
