"""Beyond-paper: policy-layer study — consolidation vs pure pinning.

Replays ONE 500-stream, 200-event churn trace (camera joins/leaves and
frame-rate renegotiations, pre-generated so every controller sees the
identical event sequence) through two controllers that differ only in
their re-planning *policy* (`core.policy`):

* **pinning** — `PinningPolicy`: the PR-2 mechanism as-is, warm re-plans
  never migrate, so removals shred residual capacity across the fleet;
* **consolidation** — `ConsolidationPolicy(k=3)` + `DualPriceAgingPolicy`:
  after each warm re-plan, evacuate up to k streams from under-filled
  bins via the batched scoring kernel + exact pinned sub-solve, adopting
  only certified cost reductions; dual prices are refreshed when the
  certified gap stays above half the threshold.

Both run with the same wide ``gap_threshold`` so the comparison isolates
the warm path: neither controller leans on full re-solves to mask drift.
Measured per trace: end-of-trace and mean hourly cost, residual-capacity
fragmentation (`simulator.fleet_fragmentation`), migration counts (the
≤ k per-event budget is asserted), and the consolidation controller's
warm re-plan latency vs sampled from-scratch solves of the same fleets.

Emits ``BENCH_policy.json`` gated by ``scripts/check_bench.py``:
consolidation must end the trace ≥ 5% cheaper than pinning while its warm
re-plans stay ≥ 5× faster than cold solves, with every event within the
k = 3 migration budget.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.catalog import paper_ec2_catalog
from repro.core.manager import ResourceManager
from repro.core.policy import (
    CompositePolicy,
    ConsolidationPolicy,
    DualPriceAgingPolicy,
    PinningPolicy,
)
from repro.core.profiler import paper_profile_table
from repro.core.simulator import fleet_fragmentation, simulate_plan
from repro.core.streams import (
    AnalysisProgram,
    StreamAdded,
    StreamRateChanged,
    StreamRemoved,
    StreamSpec,
    TimedTrace,
    apply_events,
)

from .common import record, write_json

N_STREAMS = 500
N_EVENTS = 200
COLD_EVERY = 25  # sample a from-scratch solve every k-th event
MAX_NODES = 20_000
K_MIGRATIONS = 3
GAP_THRESHOLD = 0.3  # wide: isolate the warm path (no full-resolve masking)
#: Deterministic event spacing (72 s).  Timestamps ride along on the same
#: rng-drawn event sequence (the rng draws are untouched, so the cost
#: curves — and the BENCH_policy.json floors — are bit-identical to the
#: untimed trace); benchmarks/lifecycle.py replays this exact trace
#: through the billing engine.
EVENT_GAP_H = 0.02

_VGG = AnalysisProgram("VGG-16", "vgg16")
_ZF = AnalysisProgram("ZF", "zf")
KINDS = [(_VGG, 0.25), (_VGG, 0.2), (_ZF, 0.5), (_ZF, 2.0), (_ZF, 5.0)]


def _initial_fleet() -> list[StreamSpec]:
    return [
        StreamSpec(f"s{i}", *KINDS[i % len(KINDS)]) for i in range(N_STREAMS)
    ]


def _trace(streams: list[StreamSpec], rng) -> TimedTrace:
    """Pre-generate the timed churn trace against a pure fleet replay.

    Removal-heavy mix (0.18 join / 0.52 leave / 0.30 re-rate, floored at
    half the initial fleet): leaves drain bins and fragment a pinned
    fleet, the effect this bench measures — joins self-heal it (greedy
    repair fills residual holes first), so a scale-down trace is where
    the policies separate.  Pre-generating the events (rather than
    sampling against a live controller) keeps the sequence bit-identical
    across the compared policies; given the trace, both cost curves are
    deterministic — only the timing rows vary per machine.  Events carry
    deterministic ``EVENT_GAP_H``-spaced timestamps (no extra rng draws),
    so the same trace replays through the lifecycle billing engine.
    """
    fleet = list(streams)
    events = []
    for i in range(N_EVENTS):
        at = (i + 1) * EVENT_GAP_H
        roll = rng.rand()
        if roll < 0.18 or len(fleet) < N_STREAMS // 2:
            ev = StreamAdded(
                StreamSpec(f"j{i}", *KINDS[rng.randint(len(KINDS))]), at=at
            )
        elif roll < 0.70:
            ev = StreamRemoved(fleet[rng.randint(len(fleet))].name, at=at)
        else:
            s = fleet[rng.randint(len(fleet))]
            rates = [
                fps
                for prog, fps in KINDS
                if prog.program_id == s.program.program_id
            ]
            ev = StreamRateChanged(
                s.name, rates[rng.randint(len(rates))], at=at
            )
        events.append(ev)
        fleet = list(apply_events(fleet, [ev]))
    return TimedTrace(events, horizon=(N_EVENTS + 1) * EVENT_GAP_H)


def _replay(policy, events, *, sample_cold: bool):
    """Run one policy over the trace; returns per-step records + timings."""
    table = paper_profile_table()
    mgr = ResourceManager(paper_ec2_catalog(), table, max_nodes=MAX_NODES)
    streams = _initial_fleet()
    mgr.allocate(streams)
    ctrl = mgr.controller(policy=policy, gap_threshold=GAP_THRESHOLD)
    costs: list[float] = []
    warm_us: list[float] = []
    cold_us: list[float] = []
    migrations: list[int] = []  # warm/noop events only: the policy's budget
    modes = {"warm": 0, "full": 0, "noop": 0}
    consolidations = 0
    for i, ev in enumerate(events):
        t0 = time.perf_counter()
        r = ctrl.apply(ev)
        dt = (time.perf_counter() - t0) * 1e6
        modes[r.mode] = modes.get(r.mode, 0) + 1
        costs.append(r.plan.hourly_cost)
        consolidations += sum(a.startswith("consolidate") for a in r.actions)
        # Full fallbacks re-pack (and migrate) freely and take seconds:
        # both the budget assertion and the warm-latency row are defined
        # over the policy-governed warm path only.
        if r.mode in ("warm", "noop"):
            migrations.append(len(r.migrated))
        if r.mode != "warm":
            continue
        warm_us.append(dt)
        if sample_cold and i % COLD_EVERY == 0:
            cold_mgr = ResourceManager(
                paper_ec2_catalog(), table, max_nodes=MAX_NODES
            )
            fleet = list(ctrl.fleet)
            t0 = time.perf_counter()
            cold_mgr.allocate(fleet)
            cold_us.append((time.perf_counter() - t0) * 1e6)
    sim = simulate_plan(ctrl.plan, table, target=mgr.utilization_cap)
    frag = fleet_fragmentation(sim["instances"])["overall"]
    return {
        "costs": costs,
        "warm_us": warm_us,
        "cold_us": cold_us,
        "migrations": migrations,
        "modes": modes,
        "consolidations": consolidations,
        "final_fragmentation": frag,
    }


def run() -> dict:
    rng = np.random.RandomState(1802)
    events = _trace(_initial_fleet(), rng)

    pin = _replay(PinningPolicy(), events, sample_cold=False)
    cons = _replay(
        CompositePolicy(
            ConsolidationPolicy(max_migrations=K_MIGRATIONS),
            DualPriceAgingPolicy(patience=3),
        ),
        events,
        sample_cold=True,
    )

    pin_final, cons_final = pin["costs"][-1], cons["costs"][-1]
    pin_mean = float(np.mean(pin["costs"]))
    cons_mean = float(np.mean(cons["costs"]))
    final_saving = (pin_final - cons_final) / pin_final
    mean_saving = (pin_mean - cons_mean) / pin_mean
    med_warm = float(np.median(cons["warm_us"]))
    med_cold = float(np.median(cons["cold_us"]))
    speedup = med_cold / med_warm
    # Per-event budget over warm/noop re-plans (the policy's domain).
    max_migs = max(cons["migrations"]) if cons["migrations"] else 0

    record(
        "policy/pinning_trace", 0.0,
        f"final=${pin_final:.2f} mean=${pin_mean:.2f} "
        f"frag={pin['final_fragmentation']:.3f} modes={pin['modes']}",
    )
    record(
        "policy/consolidation_trace", 0.0,
        f"final=${cons_final:.2f} mean=${cons_mean:.2f} "
        f"frag={cons['final_fragmentation']:.3f} modes={cons['modes']} "
        f"consolidations={cons['consolidations']} "
        f"migrations={sum(cons['migrations'])}",
    )
    record(
        "policy/warm_event", med_warm,
        f"p90={np.percentile(cons['warm_us'], 90):.0f}us (policy overhead incl.)",
    )
    record("policy/cold_solve", med_cold, f"n={len(cons['cold_us'])}")
    record(
        "policy/saving_vs_pinning", 0.0,
        f"final={final_saving:.1%} mean={mean_saving:.1%} "
        f"speedup={speedup:.1f}x",
    )
    out = {
        "final_cost_pinning": pin_final,
        "final_cost_consolidation": cons_final,
        "consolidation_saving": final_saving,
        "mean_saving": mean_saving,
        "speedup_warm_vs_cold": speedup,
        "median_warm_us": med_warm,
        "median_cold_us": med_cold,
        "max_migrations_per_event": max_migs,
        "migration_budget": K_MIGRATIONS,
        "consolidations": cons["consolidations"],
        "final_fragmentation_pinning": pin["final_fragmentation"],
        "final_fragmentation_consolidation": cons["final_fragmentation"],
    }
    write_json(
        "BENCH_policy.json",
        prefix="policy/",
        meta={
            "n_streams": N_STREAMS,
            "n_events": N_EVENTS,
            "max_nodes": MAX_NODES,
            "gap_threshold": GAP_THRESHOLD,
            **out,
        },
    )
    return out
