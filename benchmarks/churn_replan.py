"""Beyond-paper: live fleet churn — warm-start re-planning vs from-scratch.

Replays a 500-stream, 200-event churn trace (camera joins/leaves, frame
rate renegotiations, instance price drift) through the manager's
`FleetController` and measures what the incremental re-planner buys:

* per-event warm re-plan latency vs a from-scratch `allocate` of the same
  fleet (sampled — cold solves are seconds each at this scale),
* plan quality: the certified optimality gap of every warm plan (cost vs
  the covering-LP lower bound) and the warm/cold cost ratio on the
  sampled events,
* churn behaviour: migration counts and warm/full mode mix.

Emits ``BENCH_replan.json`` (the `scripts/perf_diff.py` row format, meta
carries the headline speedup) which `scripts/check_bench.py` gates: the
warm-start speedup must stay above its stored floor.

Since PR 10 the replay runs on *calibrated* requirement vectors: both
managers take ``calibration=`` (the committed ``CALIBRATION_ec2.json``
artifact, regenerable via ``scripts/recalibrate.py``) instead of the
hand-written paper profile table, so the churn scenario — like the
solver-scaling ladder — moves with measured model throughput.  The
gates are ratios (speedup, certified gap, warm/cold cost parity), so
they carry over unchanged.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import calibration as cal
from repro.core.catalog import paper_ec2_catalog
from repro.core.manager import ResourceManager
from repro.core.streams import (
    AnalysisProgram,
    PriceChanged,
    StreamAdded,
    StreamRateChanged,
    StreamRemoved,
    StreamSpec,
)

from .common import record, write_json

N_STREAMS = 500
N_EVENTS = 200
COLD_EVERY = 25  # sample a from-scratch solve every k-th event
MAX_NODES = 20_000
EVENT_GAP_H = 0.02  # deterministic event spacing (72 s) for the timed replay

_VGG = AnalysisProgram("VGG-16", "vgg16")
_ZF = AnalysisProgram("ZF", "zf")
#: Five stream kinds (the paper's two programs at renegotiable rates) —
#: the identical-stream multiplicity real camera fleets show.
KINDS = [(_VGG, 0.25), (_VGG, 0.2), (_ZF, 0.5), (_ZF, 2.0), (_ZF, 5.0)]


def _initial_fleet() -> list[StreamSpec]:
    return [
        StreamSpec(f"s{i}", *KINDS[i % len(KINDS)]) for i in range(N_STREAMS)
    ]


def _trace(ctrl, rng, at: float = 0.0):
    """One random timed churn event against the controller's live fleet.

    ``at`` stamps the event for the lifecycle clock; the rng draws are
    identical to the historical untimed generator, so the replayed cost
    sequence (and the stored floors) are unchanged.
    """
    roll = rng.rand()
    if roll < 0.30:
        name = f"j{rng.randint(10**9)}"
        return StreamAdded(
            StreamSpec(name, *KINDS[rng.randint(len(KINDS))]), at=at
        )
    if roll < 0.55:
        live = ctrl.fleet
        return StreamRemoved(live[rng.randint(len(live))].name, at=at)
    if roll < 0.95:
        live = ctrl.fleet
        s = live[rng.randint(len(live))]
        rates = [fps for prog, fps in KINDS if prog.program_id == s.program.program_id]
        return StreamRateChanged(s.name, rates[rng.randint(len(rates))], at=at)
    bt = ("c4.2xlarge", "c4.8xlarge", "g2.2xlarge")[rng.randint(3)]
    base = {"c4.2xlarge": 0.419, "c4.8xlarge": 1.675, "g2.2xlarge": 0.650}[bt]
    return PriceChanged(
        bt, round(base * (1.0 + 0.05 * rng.randn()), 4), at=at
    )


def run() -> dict:
    rng = np.random.RandomState(1802)
    art = cal.load_or_calibrate("ec2")
    mgr = ResourceManager(
        paper_ec2_catalog(), calibration=art, max_nodes=MAX_NODES
    )
    streams = _initial_fleet()

    t0 = time.perf_counter()
    mgr.allocate(streams)
    t_reset = (time.perf_counter() - t0) * 1e6
    ctrl = mgr.controller()
    record(
        "replan/reset", t_reset,
        f"cost=${ctrl.plan.hourly_cost:.2f} bins={len(ctrl.plan.instances)} "
        f"n={N_STREAMS}",
    )

    warm_us: list[float] = []
    single_warm_us: list[float] = []  # single-stream events only (the AC)
    cold_us: list[float] = []
    cost_ratio: list[float] = []
    gaps: list[float] = []
    migrations = 0
    modes = {"warm": 0, "full": 0, "noop": 0}
    for i in range(N_EVENTS):
        ev = _trace(ctrl, rng, at=(i + 1) * EVENT_GAP_H)
        t0 = time.perf_counter()
        r = ctrl.apply(ev)
        dt = (time.perf_counter() - t0) * 1e6
        modes[r.mode] = modes.get(r.mode, 0) + 1
        migrations += len(r.migrated)
        gaps.append(r.gap)
        if r.mode == "noop":
            continue
        warm_us.append(dt)
        if not isinstance(ev, PriceChanged):
            single_warm_us.append(dt)
        if i % COLD_EVERY == 0:
            # From-scratch solve of the identical fleet on a fresh manager
            # (no memoized formulation/tensors, same solver budget; the
            # artifact only signature-checks (name, capacity), so the
            # trace's price drift passes verify).
            cold_mgr = ResourceManager(
                tuple(mgr.catalog), calibration=art, max_nodes=MAX_NODES
            )
            fleet = list(ctrl.fleet)
            t0 = time.perf_counter()
            cold_plan = cold_mgr.allocate(fleet)
            cold_us.append((time.perf_counter() - t0) * 1e6)
            cost_ratio.append(r.plan.hourly_cost / cold_plan.hourly_cost)

    med_single = float(np.median(single_warm_us))
    med_cold = float(np.median(cold_us))
    speedup = med_cold / med_single
    record(
        "replan/warm_event", float(np.median(warm_us)),
        f"p90={np.percentile(warm_us, 90):.0f}us max_gap={max(gaps):.3%} "
        f"modes={modes} migrations={migrations}",
    )
    record(
        "replan/warm_single_stream", med_single,
        f"single-stream events only (n={len(single_warm_us)})",
    )
    record(
        "replan/cold_solve", med_cold,
        f"sampled every {COLD_EVERY} events (n={len(cold_us)})",
    )
    record(
        "replan/speedup_warm_vs_cold", 0.0,
        f"{speedup:.1f}x (warm {med_single/1e3:.1f}ms vs cold "
        f"{med_cold/1e3:.1f}ms) cost_ratio_mean={np.mean(cost_ratio):.4f}",
    )
    out = {
        "speedup_warm_vs_cold": speedup,
        "median_warm_us": med_single,
        "median_cold_us": med_cold,
        "cost_ratio_mean": float(np.mean(cost_ratio)),
        "max_certified_gap": float(max(gaps)),
        "modes": modes,
        "migrations": migrations,
    }
    write_json(
        "BENCH_replan.json",
        prefix="replan/",
        meta={
            "n_streams": N_STREAMS,
            "n_events": N_EVENTS,
            "max_nodes": MAX_NODES,
            **{k: v for k, v in out.items() if not isinstance(v, dict)},
        },
    )
    return out
