"""Paper Table 2: maximum achievable frame rates, CPU vs accelerator.

The CPU column is MEASURED on this host (real jit'd VGG-16 / ZF forward
passes). The accelerator column is dry-run derived (roofline occupancy at
v5e constants — no accelerator exists in this container), mirroring how the
resource manager estimates accelerator requirements (DESIGN.md §3).
The paper's own numbers (K40 GPU, 8-core Xeon) are printed alongside.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.profiler import TPU_V5E
from repro.core.streams import FrameSize
from repro.models.analysis_programs import PROGRAMS, make_frame, program_flops

from .common import block, record, time_us

PAPER = {"vgg16": (0.28, 3.61, 12.89), "zf": (0.56, 9.15, 16.34)}


def run() -> dict:
    out = {}
    frame = make_frame(FrameSize(640, 480))
    for prog in ("vgg16", "zf"):
        fn = PROGRAMS[prog]
        us = time_us(lambda: block(fn(jnp.asarray(frame))), iters=2, warmup=1)
        cpu_fps = 1e6 / us
        flops = program_flops(prog, FrameSize(640, 480))
        # bytes/frame ~ params + activations; compute-dominated either way.
        accel_occupancy = TPU_V5E.occupancy_per_frame(flops, flops * 0.05)
        accel_fps = 1.0 / accel_occupancy
        speedup = accel_fps / cpu_fps
        p_cpu, p_gpu, p_speed = PAPER[prog]
        record(
            f"table2/{prog}", us,
            f"cpu_fps={cpu_fps:.2f} accel_fps={accel_fps:.1f} "
            f"speedup={speedup:.1f} paper_cpu={p_cpu} paper_gpu={p_gpu} "
            f"paper_speedup={p_speed}",
        )
        out[prog] = {"cpu_fps": cpu_fps, "accel_fps": accel_fps,
                     "speedup": speedup}
    return out
