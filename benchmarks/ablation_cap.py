"""Beyond-paper ablation: the 90% utilization de-rating (paper §3).

The paper fixes the utilization cap at 90% ("maintaining the utilization
of all the resources below 90%" keeps performance ≥ 90%). This ablation
sweeps the cap and reports the cost/performance frontier on scenario 1:
lower caps buy headroom with more instances; cap=1.0 is cheapest but the
simulator shows the performance guarantee erode exactly as the paper's
Fig. 5/6 knees predict.
"""
from __future__ import annotations

from repro.core.binpack import BinType
from repro.core.manager import ResourceManager
from repro.core.profiler import paper_profile_table
from repro.core.simulator import simulate_plan
from repro.core.streams import AnalysisProgram, StreamSpec

from .common import record

CATALOG = (
    BinType("c4.2xlarge", (8, 15, 0, 0), 0.419),
    BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650),
)


def run() -> dict:
    table = paper_profile_table()
    vgg = AnalysisProgram("VGG-16", "vgg16")
    zf = AnalysisProgram("ZF", "zf")
    # A tighter fleet than scenario 1 so the cap actually binds.
    streams = [StreamSpec(f"v{i}", vgg, 1.0) for i in range(3)] + [
        StreamSpec(f"z{i}", zf, 2.0) for i in range(4)
    ]
    out = {}
    for cap in (0.6, 0.7, 0.8, 0.9, 1.0):
        mgr = ResourceManager(CATALOG, table, utilization_cap=cap)
        plan = mgr.allocate(streams)
        # The sweep judges every cap against the paper's fixed >= 90%
        # performance goal (that is the ablation); the explicit target
        # makes the choice visible instead of leaning on the default.
        sim = simulate_plan(plan, table, target=0.9)
        peak = max(max(i.utilization) for i in sim["instances"])
        record(
            f"ablation_cap/{cap:.1f}", 0.0,
            f"cost=${plan.hourly_cost:.3f} instances={len(plan.instances)} "
            f"peak_util={peak:.0%} performance={sim['overall_performance']:.0%}",
        )
        out[cap] = {"cost": plan.hourly_cost,
                    "performance": sim["overall_performance"]}
    # The paper's operating point: cheapest cap that still meets >= 90%.
    ok = [c for c, v in out.items() if v["performance"] >= 0.9]
    best = min(ok, key=lambda c: (out[c]["cost"], -c)) if ok else None
    record("ablation_cap/frontier", 0.0,
           f"cheapest_cap_meeting_90pct={best} "
           f"(paper operates at 0.9)")
    return out
