"""Beyond-paper: hierarchical sharded controller — 100k-stream pipeline.

The flat `FleetController` re-plans the whole fleet on every event: each
warm repair walks O(n)-sized tensors, so per-event latency grows linearly
with fleet size and a 100k-stream fleet is orders of magnitude past the
paper's 97-camera experiments.  `core.shard.ShardedController` partitions
the fleet into cells (here `hash_cells(512)`), routes each event to its
owning cell's warm controller, and batches per-cell heuristic repair
through ONE `jax.vmap` of `_pack_core` over padded per-cell tensors
(`heuristics.batched_pack`, fanned across devices via `jax.pmap` when
more than one is visible), with a dual-price rebalancing market
arbitraging streams across cells.

PR 9 adds the batched event pipeline: `apply_events` groups a trace by
owning cell, folds each cell's run through its warm controller with the
merged-plan rebuild amortized to once per batch (per-event results carry
lazy merged plans), and certifies the whole fleet with ONE stacked
column-generation run (`colgen.batched_dual_prices`) instead of a serial
per-cell loop.

Measured here, gated via ``BENCH_shard.json`` (`scripts/check_bench.py`):

* **100k replay** — a 100,000-stream fleet over 512 cells cold-starts
  with the batched packer and replays a mixed join/leave/re-rate trace
  through the batched pipeline; the gate requires the replay to complete
  and its mean warm per-event latency to stay under the recorded floor.
* **batched vs serial apply** — the identical trace replayed through a
  twin controller with the serial per-event loop: the batched pipeline
  must be >= 3x faster AND bit-identical (per-event hourly cost and
  certified lower bound, final placements/instances/uids, billed total;
  the delta key is the max absolute difference across all of those).
* **one-dispatch certification** — `refresh_prices()` (stacked pricing,
  one `price_knapsacks` dispatch per round across all 512 cells) vs
  `refresh_prices(batched=False)` (serial per-cell duals): >= 2x.
* **flat infeasibility probe** — the flat controller at a 5k-stream probe
  must already be >= 10x slower per warm event than the sharded
  controller on the identical fleet + events, documenting why a flat
  100k replay is not run at all.
* **vmap repair** — one `_batched_pack_raw` dispatch over the 512 live
  cell problems vs the serial numpy `_pack_raw` loop (best of 3): >= 5x.
* **cost parity** — at n=500 the 8-cell sharded replay must end within
  5% of the flat warm-start replay's hourly cost, and a single-cell
  sharded replay must match the flat cost exactly (bit-identity; the
  delta key is the max absolute per-event cost difference).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.binpack import heuristics as H
from repro.core.catalog import paper_ec2_catalog
from repro.core.controller import FleetController
from repro.core.manager import ResourceManager
from repro.core.profiler import paper_profile_table
from repro.core.shard import ShardedController, hash_cells
from repro.core.streams import (
    StreamAdded,
    StreamRateChanged,
    StreamRemoved,
    StreamSpec,
)
from repro.core.strategies import ST3

from .consolidation import KINDS
from .common import record, write_json

SEED = 7201
N_BIG = 100_000
CELLS_BIG = 512
EVENTS_BIG = 192
N_PROBE = 5_000
EVENTS_PROBE = 16
N_PARITY = 500
EVENTS_PARITY = 48
MAX_NODES = 400_000
SUB_MAX_NODES = 5_000
#: Warm-repair-only replay (storm-bench idiom): global re-certification is
#: a calm-time activity, not a per-event one, at production scale.
GAP_THRESHOLD = 10.0

#: Rates each program can actually reach (VGG-16 saturates at 0.25 FPS).
_RATES = {"vgg16": [0.2, 0.25], "zf": [0.5, 2.0, 5.0]}


def _fleet(n: int) -> list[StreamSpec]:
    return [StreamSpec(f"s{i}", *KINDS[i % len(KINDS)]) for i in range(n)]


def _events(rng, fleet, n_events):
    """Mixed join/leave/re-rate list with program-valid rates."""
    evs, t, nxt = [], 0.0, len(fleet)
    prog = {s.name: s.program.program_id for s in fleet}
    names = [s.name for s in fleet]
    for _ in range(n_events):
        t += 0.01
        roll = rng.rand()
        if roll < 0.3 or not names:
            kind = KINDS[nxt % len(KINDS)]
            name = f"j{nxt}"
            nxt += 1
            evs.append(StreamAdded(StreamSpec(name, *kind), at=t))
            names.append(name)
            prog[name] = kind[0].program_id
        elif roll < 0.55:
            name = names.pop(int(rng.rand() * len(names)))
            evs.append(StreamRemoved(name, at=t))
        else:
            name = names[int(rng.rand() * len(names))]
            rates = _RATES[prog[name]]
            evs.append(
                StreamRateChanged(name, rates[rng.randint(len(rates))], at=t)
            )
    return evs


def _manager(**kw) -> ResourceManager:
    kw.setdefault("max_nodes", MAX_NODES)
    return ResourceManager(paper_ec2_catalog(), paper_profile_table(), **kw)


def _replay_us(ctrl, events) -> float:
    """Mean wall-time per applied event, in microseconds."""
    t0 = time.perf_counter()
    for ev in events:
        ctrl.apply(ev)
    return (time.perf_counter() - t0) / len(events) * 1e6


def _build_big(streams) -> ShardedController:
    sc = ShardedController(
        _manager(),
        ST3,
        cell_key=hash_cells(CELLS_BIG),
        sub_max_nodes=SUB_MAX_NODES,
        gap_threshold=GAP_THRESHOLD,
    )
    sc.reset(streams, at=0.0, pack="batched")
    return sc


def _big_replay(meta: dict) -> ShardedController:
    """100k streams / 512 cells: batched pipeline vs serial loop on the
    identical trace from identical cold starts, then one-dispatch vs
    per-cell certification on the resulting warm fleets."""
    streams = _fleet(N_BIG)
    t0 = time.perf_counter()
    serial = _build_big(streams)
    reset_s = time.perf_counter() - t0
    batched = _build_big(streams)
    assert len(batched.fleet) == N_BIG and batched.n_cells == CELLS_BIG
    events = _events(np.random.RandomState(SEED), streams, EVENTS_BIG)

    # Certification first, on the identical cold-start fleets: ONE
    # stacked colgen run vs the serial per-cell dual-price loop.  The
    # batched side's untimed first run pays the shared column pool's
    # cold start (recorded separately); the timed run is the steady-state
    # re-certification `refresh_prices`/`rebalance` quote from.
    t0 = time.perf_counter()
    lb_serial = serial.refresh_prices(batched=False)
    certify_serial_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched.refresh_prices()
    certify_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    lb_batched = batched.refresh_prices()
    certify_s = time.perf_counter() - t0
    assert 0.0 < lb_batched <= batched.total_cost() + 1e-6
    assert 0.0 < lb_serial <= serial.total_cost() + 1e-6
    certify_speedup = certify_serial_s / certify_s
    # Re-install the serial side's exact per-cell duals on BOTH twins so
    # the apply comparison starts from identical price state (colgen's
    # Farley-scaled duals are admissible but not bit-equal to arcflow's);
    # per-event certification is a calm-time activity, off the hot path.
    batched.refresh_prices(batched=False)

    # Serial reference: the pre-PR-9 per-event loop (`apply_events(...,
    # batched=False)` is exactly this).  Streamed so only ONE eagerly
    # merged 100k-placement plan is alive at a time; the batched side's
    # lazy plans are a few hundred bytes each.
    t0 = time.perf_counter()
    serial_costs, serial_lbs, last = [], [], None
    for ev in events:
        last = serial.apply(ev)
        serial_costs.append(last.plan.hourly_cost)
        serial_lbs.append(last.lower_bound)
    serial_apply_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rb = batched.apply_events(events)
    batched_apply_s = time.perf_counter() - t0
    speedup = serial_apply_s / batched_apply_s
    mean_us = batched_apply_s / len(events) * 1e6

    # Bit-identity: per-event certified numbers plus the final fleet.
    # (Materializing the final lazy plan happens here, outside the timed
    # region — deferring exactly that O(fleet) rebuild is the speedup.)
    delta = max(
        max(abs(x - y.plan.hourly_cost) for x, y in zip(serial_costs, rb)),
        max(abs(x - y.lower_bound) for x, y in zip(serial_lbs, rb)),
    )
    final_s, final_b = last.plan, rb[-1].plan
    horizon = events[-1].at + 1.0
    if (
        final_s.placements != final_b.placements
        or final_s.instances != final_b.instances
        or serial.instance_uids != batched.instance_uids
        or serial.lifecycle.billed_cost(horizon)
        != batched.lifecycle.billed_cost(horizon)
    ):
        delta = float("inf")

    st = batched.stats()
    meta["sharded_streams"] = N_BIG
    meta["sharded_cells"] = CELLS_BIG
    meta["sharded_reset_s"] = reset_s
    meta["mean_warm_event_us"] = mean_us
    meta["serial_apply_s"] = serial_apply_s
    meta["batched_apply_s"] = batched_apply_s
    meta["batched_apply_speedup"] = speedup
    meta["batched_apply_delta"] = delta
    meta["batched_certify_s"] = certify_s
    meta["batched_certify_cold_s"] = certify_cold_s
    meta["serial_certify_s"] = certify_serial_s
    meta["batched_certify_speedup"] = certify_speedup
    meta["pipeline_events_routed"] = st["events_routed"]
    meta["pipeline_batch_barriers"] = st["batch_barriers"]
    meta["pipeline_seg_cache_hits"] = st["seg_cache_hits"]
    meta["pipeline_seg_cache_misses"] = st["seg_cache_misses"]
    meta["pipeline_batched_repair_dispatches"] = st["batched_repair_dispatches"]
    meta["pipeline_serial_repair_dispatches"] = st["serial_repair_dispatches"]
    meta["pipeline_pricing_dispatches"] = st["pricing_dispatches"]
    meta["pipeline_pricing_rounds"] = st["pricing_rounds"]
    record("shard/reset_100k_batched", reset_s * 1e6, f"{CELLS_BIG} cells")
    record(
        "shard/apply_serial_100k",
        serial_apply_s * 1e6,
        f"{EVENTS_BIG} events, per-event merged plans",
    )
    record(
        "shard/apply_batched_100k",
        batched_apply_s * 1e6,
        f"{speedup:.1f}x vs serial, delta {delta:g}",
    )
    record(
        "shard/warm_event_100k",
        mean_us,
        f"{EVENTS_BIG} events, cost ${batched.total_cost():.0f}/h",
    )
    record(
        "shard/certify_serial_100k",
        certify_serial_s * 1e6,
        "per-cell dual prices",
    )
    record(
        "shard/certify_batched_100k",
        certify_s * 1e6,
        f"{certify_speedup:.1f}x, {st['pricing_dispatches']} dispatches "
        f"/ {st['pricing_rounds']} rounds",
    )
    return batched


def _flat_probe(meta: dict) -> None:
    """Per-event latency, flat vs sharded, on the identical 5k fleet."""
    streams = _fleet(N_PROBE)
    events = _events(np.random.RandomState(SEED + 1), streams, EVENTS_PROBE)
    # Tiny node budget: the cold solves fall to their heuristic incumbent
    # fast — this probe times the *warm event path*, not the cold start.
    # Prices are refreshed up front on both sides so neither pays its
    # one-time certification inside the timed replay.
    flat = FleetController(
        _manager(max_nodes=500),
        ST3,
        sub_max_nodes=SUB_MAX_NODES,
        gap_threshold=GAP_THRESHOLD,
    )
    flat.reset(streams, at=0.0)
    flat.refresh_prices()
    flat_us = _replay_us(flat, events)
    sc = ShardedController(
        _manager(max_nodes=500),
        ST3,
        cell_key=hash_cells(CELLS_BIG),
        sub_max_nodes=SUB_MAX_NODES,
        gap_threshold=GAP_THRESHOLD,
    )
    sc.reset(streams, at=0.0, pack="batched")
    sc.refresh_prices()
    shard_us = _replay_us(sc, events)
    ratio = flat_us / shard_us
    meta["flat_vs_sharded_event_ratio_5k"] = ratio
    record("shard/flat_event_5k", flat_us, "flat warm event at 5k streams")
    record("shard/sharded_event_5k", shard_us, f"flat/sharded = {ratio:.1f}x")


def _vmap_repair(meta: dict, sc: ShardedController) -> None:
    """Batched `_pack_core` vs the serial numpy `_pack_raw` loop on the
    live per-cell problems of the 20k fleet.  Both sides produce the
    identical (placements, opened) decisions; `Solution` materialization
    is the same code either way and is timed separately as decode."""
    probs = [
        cell._problem
        for cell in sc.cells.values()
        if cell._problem is not None and cell._problem.items
    ]
    H._batched_pack_raw(probs)  # compile outside the timed region
    vmap_s, serial_s = float("inf"), float("inf")
    for _ in range(3):  # best-of-3: both paths are deterministic
        t0 = time.perf_counter()
        batched = H._batched_pack_raw(probs)
        vmap_s = min(vmap_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        serial = [H._pack_raw(p, False) for p in probs]
        serial_s = min(serial_s, time.perf_counter() - t0)
    assert [placements for placements, _ in batched] == [
        placements for placements, _ in serial
    ]
    t0 = time.perf_counter()
    sols = H.batched_pack(probs)
    decode_s = time.perf_counter() - t0 - vmap_s
    assert len(sols) == len(probs)
    speedup = serial_s / vmap_s
    meta["vmap_repair_cells"] = len(probs)
    meta["vmap_repair_speedup"] = speedup
    record("shard/repair_serial", serial_s * 1e6, f"{len(probs)} cells")
    record("shard/repair_vmap", vmap_s * 1e6, f"{speedup:.1f}x vs serial")
    record(
        "shard/repair_decode",
        max(decode_s, 0.0) * 1e6,
        "shared Solution materialization",
    )


def _cost_parity(meta: dict) -> None:
    streams = _fleet(N_PARITY)
    events = _events(np.random.RandomState(SEED + 2), streams, EVENTS_PARITY)

    def replay(ctrl):
        costs = [ctrl.reset(streams, at=0.0).plan.hourly_cost]
        costs += [ctrl.apply(ev).plan.hourly_cost for ev in events]
        return costs

    flat = replay(FleetController(_manager(), ST3, sub_max_nodes=SUB_MAX_NODES))
    one = replay(ShardedController(_manager(), ST3, sub_max_nodes=SUB_MAX_NODES))
    eight = replay(
        ShardedController(
            _manager(),
            ST3,
            cell_key=hash_cells(8),
            sub_max_nodes=SUB_MAX_NODES,
            rebalance_every=8,
        )
    )
    delta = max(abs(a - b) for a, b in zip(flat, one))
    ratio = eight[-1] / flat[-1]
    meta["single_cell_cost_delta"] = delta
    meta["cost_ratio_n500"] = ratio
    record(
        "shard/parity_flat_500", 0.0, f"final cost ${flat[-1]:.2f}/h"
    )
    record(
        "shard/parity_8cell_500",
        0.0,
        f"final cost ${eight[-1]:.2f}/h ({ratio:.3f}x flat)",
    )


def run() -> dict:
    meta: dict = {}
    # Small probes first: their short timing loops are sensitive to gen-2
    # GC pauses once the 100k fleet's millions of objects are alive.
    _flat_probe(meta)
    _cost_parity(meta)
    sc = _big_replay(meta)
    _vmap_repair(meta, sc)
    write_json("BENCH_shard.json", prefix="shard/", meta=meta)
    return meta


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
