"""Beyond-paper: spot/preemptible instance study — risk-aware allocation.

Real clouds sell the same instance shape at two prices: on-demand, and
spot at a deep discount paid for in *preemption risk*.  This suite builds
a two-tier market over the paper catalog — each shape gets a
cheap-but-flaky spot pool (30% of on-demand, λ = 0.9 interruptions per
instance-hour) and a dearer-but-stable one (45%, λ = 0.08), the
per-pool (price, interruption-frequency) menu real spot markets publish —
and replays ONE seeded preemption-heavy 500-stream timed trace
(`streams.synthetic_timed_trace(preemption_hazard=...)`: churn plus a
Poisson shock stream, per-type-thinned at replay so every compared policy
sees the identical events yet each spot type dies at its own catalog λ)
through four allocators:

* **ondemand** — the spot-blind baseline: on-demand types only.  Never
  preempted, pays full rent.
* **naive_spot** — cost-greedy over the raw two-tier catalog: the solver
  sees only rent, so it buys the deepest discount (the flaky pool) and
  pays in preemption churn — streams go down for a replacement boot on
  every interruption.
* **risk_aware** — the same catalog priced through
  `policy.risk_adjusted_catalog`: spot decision costs carry
  rent + λ × (re-placement penalty), so the packer buys the *stable*
  pool's discount and the flaky pool only when its rent survives its
  risk.  Billing still runs on true rents (`BinType.billed_rent`), and
  spot pools bill per-second next to hourly on-demand via the per-type
  `billing_by_type` map (`LifecycleEngine.billing_for`).
* **risk_acting** — risk_aware plus `ActingAutoscaler` holding warm
  spares ahead of an oracle join forecast, with ``max_spare_hazard``
  refusing unreliable pools: spares come from the stable tier (or
  on-demand), never the flaky one.

Gated via ``BENCH_spot.json`` (`scripts/check_bench.py`): risk-aware must
bill >= 10% less than all-on-demand while its preemption-caused degraded
stream-seconds stay no worse than naive all-spot's, the naive run must
demonstrably lose on degraded time, the on-demand run must never be
preempted, and the acting run must hold no unreliable spares.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.catalog import paper_ec2_catalog, with_spot_variants
from repro.core.lifecycle import BillingModel
from repro.core.manager import ResourceManager
from repro.core.policy import (
    ActingAutoscaler,
    PinningPolicy,
    risk_adjusted_catalog,
)
from repro.core.profiler import paper_profile_table
from repro.core.simulator import simulate_churn
from repro.core.streams import (
    InstancePreempted,
    StreamAdded,
    StreamForecast,
    StreamSpec,
    synthetic_timed_trace,
)

from . import consolidation
from .common import record, write_json

BOOT_H = 2.0 / 60.0
HOURLY = BillingModel(boot_hours=BOOT_H, quantum_hours=1.0)
#: Spot pools bill per-second (continuous is the per-second limit at
#: hour-scale horizons) — the per-type contract map's reason to exist.
SPOT_BILL = BillingModel(boot_hours=BOOT_H, quantum_hours=0.0)

FLAKY_RATIO, FLAKY_HAZARD = 0.30, 0.9  # deep discount, reclaimed constantly
STABLE_RATIO, STABLE_HAZARD = 0.45, 0.08  # modest discount, rarely reclaimed
DEGRADED_PENALTY = 25.0  # $ per stream-hour of post-preemption downtime
HAZARD_POOL = 192  # thinning pool: >= max concurrent spot instances
N_EVENTS = 80
MEAN_GAP_H = 0.02
LOOKAHEAD_H = 0.15
MAX_SPARES = 3
MAX_SPARE_HAZARD = 0.1  # tolerate the stable pool, refuse the flaky one
GAP_THRESHOLD = 0.3
SEED = 7113


def _market():
    """(on-demand catalog, two-tier spot catalog, per-type billing map)."""
    base = paper_ec2_catalog()
    cat = with_spot_variants(
        base, price_ratio=FLAKY_RATIO, hazard=FLAKY_HAZARD, suffix="-spot"
    )
    cat = with_spot_variants(
        cat,
        price_ratio=STABLE_RATIO,
        hazard=STABLE_HAZARD,
        suffix="-spot-stable",
    )
    by_type = {bt.name: SPOT_BILL for bt in cat if bt.is_spot}
    return base, cat, by_type


def _trace(initial):
    """Seeded preemption-heavy churn: joins/leaves/re-rates + spot shocks."""
    rng = np.random.RandomState(SEED)
    kinds = consolidation.KINDS

    def make_join(i):
        return StreamSpec(f"g{i}", *kinds[i % len(kinds)])

    return synthetic_timed_trace(
        initial,
        rng,
        n_events=N_EVENTS,
        mean_gap_hours=MEAN_GAP_H,
        p_join=0.45,
        p_leave=0.2,
        make_join=make_join,
        rerate_fps=lambda s: [
            fps
            for prog, fps in kinds
            if prog.program_id == s.program.program_id
        ],
        burst=2,
        tail_hours=0.3,
        preemption_hazard=FLAKY_HAZARD,  # reference = the max catalog λ
        hazard_pool=HAZARD_POOL,
    )


def _oracle_forecast(trace):
    """Perfect short-horizon join forecaster read off the trace itself."""
    adds = [(ev.at, ev.stream) for ev in trace if isinstance(ev, StreamAdded)]

    def forecast(fleet, event):
        now = event.at if event is not None else 0.0
        live = {s.name for s in fleet}
        upcoming = tuple(
            s
            for t, s in adds
            if now < t <= now + LOOKAHEAD_H and s.name not in live
        )
        return StreamForecast(joins=upcoming[:MAX_SPARES])

    return forecast


def _replay(catalog, initial, trace, by_type, *, policy):
    mgr = ResourceManager(
        catalog, paper_profile_table(), max_nodes=consolidation.MAX_NODES
    )
    mgr.controller(gap_threshold=GAP_THRESHOLD)
    return simulate_churn(
        mgr,
        initial,
        trace,
        paper_profile_table(),
        policy=policy,
        billing=HOURLY,
        billing_by_type=by_type,
    )


def _join_degraded(out) -> float:
    """Degraded stream-seconds from join/reset boots only (the initial
    reset boot is identical across runs; preemption waits are broken out
    by the simulator already)."""
    reset = out["timeline"][0]["boot_wait_stream_hours"] * 3600.0
    return (
        out["degraded_stream_seconds"]
        - out["preemption_degraded_stream_seconds"]
        - reset
    )


def run() -> dict:
    base, spot_cat, by_type = _market()
    risk_cat = risk_adjusted_catalog(
        spot_cat,
        HOURLY,
        billing_by_type=by_type,
        degraded_penalty=DEGRADED_PENALTY,
    )
    initial = consolidation._initial_fleet()
    trace = _trace(initial)
    shocks = sum(isinstance(ev, InstancePreempted) for ev in trace)

    runs = {}
    for name, catalog, policy in (
        ("ondemand", base, PinningPolicy()),
        ("naive_spot", spot_cat, PinningPolicy()),
        ("risk_aware", risk_cat, PinningPolicy()),
        (
            "risk_acting",
            risk_cat,
            ActingAutoscaler(
                forecast=_oracle_forecast(trace),
                max_spares=MAX_SPARES,
                max_spare_hazard=MAX_SPARE_HAZARD,
            ),
        ),
    ):
        t0 = time.perf_counter()
        out = _replay(catalog, initial, trace, by_type, policy=policy)
        dt = time.perf_counter() - t0
        runs[name] = out
        record(
            f"spot/{name}", dt * 1e6,
            f"billed=${out['billed_cost']:.2f} "
            f"preemptions={out['preemptions']} "
            f"preempt_degraded={out['preemption_degraded_stream_seconds']:.0f}s "
            f"join_degraded={_join_degraded(out):.0f}s",
        )

    od, naive, risk, acting = (
        runs["ondemand"],
        runs["naive_spot"],
        runs["risk_aware"],
        runs["risk_acting"],
    )
    risk_saving = 1.0 - risk["billed_cost"] / od["billed_cost"]
    naive_saving = 1.0 - naive["billed_cost"] / od["billed_cost"]
    degraded_excess = (
        risk["preemption_degraded_stream_seconds"]
        - naive["preemption_degraded_stream_seconds"]
    )
    hazard_of = {bt.name: bt.hazard for bt in risk_cat}
    unreliable_spares = sum(
        hazard_of.get(a.rsplit(":", 1)[-1], 0.0) > MAX_SPARE_HAZARD
        for t in acting["timeline"]
        for a in t["actions"]
        if a.startswith("autoscale:provision:")
    )
    acting_join_cut = 1.0 - _join_degraded(acting) / max(
        _join_degraded(risk), 1e-12
    )
    acting_overhead = acting["billed_cost"] / risk["billed_cost"] - 1.0

    out = {
        "billed_cost_ondemand": od["billed_cost"],
        "billed_cost_naive_spot": naive["billed_cost"],
        "billed_cost_risk_aware": risk["billed_cost"],
        "billed_cost_risk_acting": acting["billed_cost"],
        "risk_aware_billed_saving": risk_saving,
        "naive_spot_billed_saving": naive_saving,
        "preemptions_ondemand": od["preemptions"],
        "preemptions_naive_spot": naive["preemptions"],
        "preemptions_risk_aware": risk["preemptions"],
        "preempt_degraded_seconds_naive_spot": naive[
            "preemption_degraded_stream_seconds"
        ],
        "preempt_degraded_seconds_risk_aware": risk[
            "preemption_degraded_stream_seconds"
        ],
        "risk_vs_naive_degraded_excess": degraded_excess,
        "acting_join_degraded_cut": acting_join_cut,
        "acting_billed_overhead": acting_overhead,
        "acting_unreliable_spares": unreliable_spares,
        "trace_shocks": shocks,
    }
    record(
        "spot/summary", 0.0,
        f"risk_saving={risk_saving:.1%} naive_saving={naive_saving:.1%} "
        f"degraded risk={risk['preemption_degraded_stream_seconds']:.0f}s vs "
        f"naive={naive['preemption_degraded_stream_seconds']:.0f}s "
        f"acting_join_cut={acting_join_cut:.0%}@{acting_overhead:+.2%}",
    )
    write_json(
        "BENCH_spot.json",
        prefix="spot/",
        meta={
            "n_streams": consolidation.N_STREAMS,
            "n_churn_events": N_EVENTS,
            "hazard_pool": HAZARD_POOL,
            "flaky_hazard": FLAKY_HAZARD,
            "stable_hazard": STABLE_HAZARD,
            "degraded_penalty": DEGRADED_PENALTY,
            **out,
        },
    )
    return out
