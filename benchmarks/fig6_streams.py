"""Paper Fig. 6: number of streams vs utilization vs performance.

VGG-16 at 2 FPS on the accelerator, adding cameras to one instance until
it overloads — utilization grows linearly, performance drops past the
saturation point.
"""
from __future__ import annotations

from repro.core.binpack import BinType
from repro.core.profiler import paper_profile_table
from repro.core.simulator import simulate_instance

from .common import record

GPU_BOX = BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650)


def run() -> dict:
    prof = paper_profile_table().get("vgg16", "640x480", "accel")
    req = prof.at_fps(2.0)
    rows = []
    for n in (1, 2, 3, 4, 6, 8):
        info = simulate_instance(GPU_BOX, [req] * n)
        rows.append((n, info.utilization[0], info.utilization[2],
                     info.performance))
        record(
            f"fig6/vgg16x{n}@2fps", 0.0,
            f"cpu_util={info.utilization[0]:.2f} "
            f"gpu_util={info.utilization[2]:.3f} "
            f"performance={info.performance:.2f}",
        )
    # Linear growth while under capacity.
    linear = abs(rows[1][1] / rows[0][1] - 2.0) < 1e-6
    knee = next((n for n, c, g, p in rows if p < 1.0), None)
    record("fig6/summary", 0.0, f"linear={linear} perf_knee_streams={knee}")
    return {"rows": rows, "linear": linear, "knee": knee}
