"""Beyond-paper: solver scaling study — exact vs arc-flow DP vs heuristics.

Random heterogeneous fleets of growing size; reports solve time and cost
gap of FFD vs the exact optimum (quantifying what the paper's exact
formulation buys over a greedy allocator).

Post-vectorization this sweep covers what the seed implementation could
not: n=200 exact (budgeted) bin-completion solves, and n=500 arc-flow
fleets over multi-kind (5–10 stream class) catalogs, where the solver
reports its LP lower bound so budgeted runs carry a certified optimality
gap.  `SEED_BASELINE_US` pins the seed (pre-vectorization) timings
measured on the same scenarios, so the emitted speedup column tracks the
refactor's win; `BENCH_solver.json` (via `common.write_json`) is the
artifact `scripts/perf_diff.py solver` diffs against future PRs.
"""
from __future__ import annotations

import numpy as np

from repro.core.binpack import (
    BinType, Choice, Item, Problem,
    first_fit_decreasing, solve, solve_arcflow,
)

from .common import record, time_us, write_json


def _timed(fn):
    """One measured call (the big solves are too slow to run thrice)."""
    import time

    t0 = time.perf_counter()
    result = fn()
    return (time.perf_counter() - t0) * 1e6, result

CATALOG = (
    BinType("c4.2xlarge", (8, 15, 0, 0), 0.419),
    BinType("c4.8xlarge", (36, 60, 0, 0), 1.675),
    BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650),
)

#: Seed-implementation wall times (µs) on this module's scenarios, recorded
#: before the ProblemTensors vectorization (same machine class, max_nodes =
#: 60k).  The benchmark reports current time / seed time per row.
SEED_BASELINE_US = {
    "solver/n8/exact": 5_900.0,
    "solver/n12/exact": 72_800.0,
    "solver/n16/exact": 477_700.0,
    "solver/n16/arcflow": 51_600.0,
    "solver/n16/ffd": 2_470.0,
}


def _fleet(n: int, seed: int, n_kinds: int = 3):
    """n streams drawn from n_kinds profiles (identical-item structure
    mirrors real camera fleets and feeds the arc-flow grouping)."""
    rng = np.random.RandomState(seed)
    kinds = []
    for k in range(n_kinds):
        cpu = rng.uniform(1.0, 5.0)
        kinds.append((
            (cpu, rng.uniform(0.2, 1.0), 0.0, 0.0),
            (cpu * 0.13, rng.uniform(0.2, 1.0), rng.uniform(30, 300),
             rng.uniform(0.1, 0.6)),
        ))
    items = []
    for i in range(n):
        c, g = kinds[i % n_kinds]
        items.append(Item(f"s{i}", (Choice("cpu", c), Choice("accel", g))))
    return Problem(bin_types=CATALOG, items=tuple(items))


def _speedup(name: str, us: float) -> str:
    base = SEED_BASELINE_US.get(name)
    return f" speedup_vs_seed={base / us:.1f}x" if base and us > 0 else ""


def run() -> dict:
    out = {}
    for n in (4, 8, 12, 16):
        p = _fleet(n, seed=n)
        t_exact = time_us(lambda: solve(p, max_nodes=60_000), iters=1)
        sol, stats = solve(p, max_nodes=60_000)
        t_ffd = time_us(lambda: first_fit_decreasing(p), iters=3)
        ffd = first_fit_decreasing(p)
        t_af = time_us(lambda: solve_arcflow(p), iters=1)
        af, af_stats = solve_arcflow(p)
        gap = (ffd.cost - sol.cost) / sol.cost if sol.cost else 0.0
        record(
            f"solver/n{n}/exact", t_exact,
            f"cost=${sol.cost:.3f} nodes={stats.nodes} optimal={stats.optimal}"
            + _speedup(f"solver/n{n}/exact", t_exact),
        )
        record(
            f"solver/n{n}/arcflow", t_af,
            f"cost=${af.cost:.3f} patterns={af_stats.n_patterns} "
            f"classes={af_stats.n_classes} agree={abs(af.cost-sol.cost)<1e-6}"
            + _speedup(f"solver/n{n}/arcflow", t_af),
        )
        record(f"solver/n{n}/ffd", t_ffd,
               f"cost=${ffd.cost:.3f} gap_vs_exact={gap:.1%}"
               + _speedup(f"solver/n{n}/ffd", t_ffd))
        out[n] = {"exact": sol.cost, "ffd": ffd.cost, "arcflow": af.cost}

    # Mid-size fleets: arc-flow DP (exact; identical-stream grouping keeps
    # the demand lattice small — this is why the paper's VPSolver scales).
    for n in (24, 48, 96):
        p = _fleet(n, seed=n)
        t_af, (af, af_stats) = _timed(lambda: solve_arcflow(p))
        ffd = first_fit_decreasing(p)
        record(
            f"solver/n{n}/arcflow_only", t_af,
            f"cost=${af.cost:.3f} ffd=${ffd.cost:.3f} "
            f"gain_vs_ffd={(ffd.cost - af.cost) / ffd.cost:.0%} "
            f"optimal={af_stats.optimal}",
        )
        out[n] = {"arcflow": af.cost, "ffd": ffd.cost}

    # Large-fleet frontier (seed implementation topped out at n=96 / 16):
    # n=200 exact (budgeted B&B returns the incumbent), n=200/n=500
    # multi-kind arc-flow (which certifies its gap against the covering-LP
    # lower bound when the state budget is hit), and a 10-class n=500
    # catalog on the budgeted B&B + heuristics.
    p200 = _fleet(200, seed=200, n_kinds=5)
    t_exact, (sol, stats) = _timed(lambda: solve(p200, max_nodes=20_000))
    record(
        "solver/n200k5/exact", t_exact,
        f"cost=${sol.cost:.3f} nodes={stats.nodes} optimal={stats.optimal}",
    )
    out["200exact"] = {"exact": sol.cost}
    for n, kinds, budget in ((200, 5, 40_000), (500, 5, 40_000)):
        p = _fleet(n, seed=n, n_kinds=kinds)
        t_af, (af, af_stats) = _timed(
            lambda: solve_arcflow(p, max_dp_states=budget)
        )
        af.validate()
        ffd = first_fit_decreasing(p)
        gap = (
            (af.cost - af_stats.lp_bound) / af_stats.lp_bound
            if af_stats.lp_bound > 0
            else 0.0
        )
        record(
            f"solver/n{n}k{kinds}/arcflow", t_af,
            f"cost=${af.cost:.3f} ffd=${ffd.cost:.3f} lp_bound=${af_stats.lp_bound:.3f} "
            f"gap<={gap:.2%} states={af_stats.dp_states} optimal={af_stats.optimal}",
        )
        out[f"{n}k{kinds}"] = {"arcflow": af.cost, "ffd": ffd.cost,
                               "lp_bound": af_stats.lp_bound}
    p10 = _fleet(500, seed=500, n_kinds=10)
    t_ffd, ffd10 = _timed(lambda: first_fit_decreasing(p10))
    t_bc, (bc10, bc_stats) = _timed(lambda: solve(p10, max_nodes=5_000))
    record(
        "solver/n500k10/ffd", t_ffd,
        f"cost=${ffd10.cost:.3f} bins={len(ffd10.bins)}",
    )
    record(
        "solver/n500k10/exact_budget", t_bc,
        f"cost=${bc10.cost:.3f} nodes={bc_stats.nodes} optimal={bc_stats.optimal} "
        f"gain_vs_ffd={(ffd10.cost - bc10.cost) / ffd10.cost:.0%}",
    )
    out["500k10"] = {"ffd": ffd10.cost, "exact_budget": bc10.cost}

    write_json(
        "BENCH_solver.json",
        prefix="solver/",
        meta={"seed_baseline_us": SEED_BASELINE_US},
    )
    return out
