"""Beyond-paper: solver scaling study — exact vs arc-flow DP vs heuristics.

Random heterogeneous fleets of growing size; reports solve time and cost
gap of FFD vs the exact optimum (quantifying what the paper's exact
formulation buys over a greedy allocator).

Post-vectorization this sweep covers what the seed implementation could
not: n=200 exact (budgeted) bin-completion solves, and n=500 arc-flow
fleets over multi-kind (5–10 stream class) catalogs, where the solver
reports its LP lower bound so budgeted runs carry a certified optimality
gap.  `SEED_BASELINE_US` pins the seed (pre-vectorization) timings
measured on the same scenarios, so the emitted speedup column tracks the
refactor's win; `BENCH_solver.json` (via `common.write_json`) is the
artifact `scripts/perf_diff.py solver` diffs against future PRs.

PR 8 adds the branch-and-price ladder: `solve_colgen` vs budgeted
arc-flow enumeration at n=200/500 x 4/8/10 stream kinds (both gaps
measured against colgen's Farley-certified lower bound — one honest LB),
plus a pricing-kernel microbenchmark (one batched jax dispatch over all
branch nodes x bin kinds vs the serial per-kind numpy reference loop on
the same inputs) and an impl bit-equivalence probe.  Headline metrics
land in the artifact's ``meta`` and are gated by
`scripts/check_bench.py`: colgen certified gap <= 1% on the n=500 /
10-kind fleet where budgeted enumeration strands >= 5% above the same
bound, batched pricing >= 3x over the serial loop, bit-identical
kernels.

PR 10 re-bases the scaling study on *calibrated* scenarios: a second
ladder (``solver/cal_*`` rows) and the pricing-kernel grid draw their
stream kinds from the committed EC2 calibration artifact
(`core.calibration.stream_kinds` — the paper's programs at fractions of
each program's calibrated max rate) and every requirement vector from
`requirements_from_calibration`, so rerunning `scripts/recalibrate.py`
after a kernel/hardware change re-derives the exact fleets these gates
certify (new gate: colgen certifies <= 1% on the calibrated n=500 /
10-kind fleet).  The historical synthetic ladder stays: its random
kinds are deliberately adversarial — wide independent per-dimension
spreads no measured program mix produces — and they are what makes
budgeted enumeration strand >= 5% where branch-and-price certifies;
calibrated fleets at paper-realistic rates have too much
identical-stream structure to separate the two solvers.  (The
`SEED_BASELINE_US` speedup columns are likewise only meaningful on the
scenarios the seed timings were recorded on.)
"""
from __future__ import annotations

import numpy as np

from repro.core import calibration as cal
from repro.core.binpack import (
    BinType, Choice, Item, Problem,
    first_fit_decreasing, solve, solve_arcflow, solve_colgen,
)
from repro.core.catalog import paper_ec2_catalog

from .common import record, time_us, write_json


def _timed(fn):
    """One measured call (the big solves are too slow to run thrice)."""
    import time

    t0 = time.perf_counter()
    result = fn()
    return (time.perf_counter() - t0) * 1e6, result

CATALOG = (
    BinType("c4.2xlarge", (8, 15, 0, 0), 0.419),
    BinType("c4.8xlarge", (36, 60, 0, 0), 1.675),
    BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650),
)

#: Seed-implementation wall times (µs) on this module's scenarios, recorded
#: before the ProblemTensors vectorization (same machine class, max_nodes =
#: 60k).  The benchmark reports current time / seed time per row.
SEED_BASELINE_US = {
    "solver/n8/exact": 5_900.0,
    "solver/n12/exact": 72_800.0,
    "solver/n16/exact": 477_700.0,
    "solver/n16/arcflow": 51_600.0,
    "solver/n16/ffd": 2_470.0,
}


def _fleet(n: int, seed: int, n_kinds: int = 3):
    """n streams drawn from n_kinds profiles (identical-item structure
    mirrors real camera fleets and feeds the arc-flow grouping)."""
    rng = np.random.RandomState(seed)
    kinds = []
    for k in range(n_kinds):
        cpu = rng.uniform(1.0, 5.0)
        kinds.append((
            (cpu, rng.uniform(0.2, 1.0), 0.0, 0.0),
            (cpu * 0.13, rng.uniform(0.2, 1.0), rng.uniform(30, 300),
             rng.uniform(0.1, 0.6)),
        ))
    items = []
    for i in range(n):
        c, g = kinds[i % n_kinds]
        items.append(Item(f"s{i}", (Choice("cpu", c), Choice("accel", g))))
    return Problem(bin_types=CATALOG, items=tuple(items))


_ARTIFACT = None

#: Rate fractions for the calibrated ladder: fractions of each program's
#: calibrated max rate, capped so every kind fits the g2.2xlarge under
#: the 90% utilization cap (the artifact's per-dimension max-rate clamp
#: is catalog-wide, so joint single-bin feasibility caps out earlier).
_CAL_FRACTIONS = (0.03, 0.06, 0.1, 0.13, 0.16)


def _calibrated_fleet(n: int, n_kinds: int) -> Problem:
    """n streams over n_kinds *calibrated* kinds on the paper's catalog.

    Deterministic and regenerable: the kinds ladder and every requirement
    vector come straight from ``CALIBRATION_ec2.json``
    (`scripts/recalibrate.py` re-derives it from the profiler/roofline
    path), so these gated scenarios move with measured throughput, not
    with a random-kind generator's constants.
    """
    global _ARTIFACT
    if _ARTIFACT is None:
        _ARTIFACT = cal.load_or_calibrate("ec2")
    kinds = cal.stream_kinds(_ARTIFACT, n_kinds, fps_fractions=_CAL_FRACTIONS)
    streams = cal.stream_mix(_ARTIFACT, n, kinds=kinds)
    items = cal.requirements_from_calibration(_ARTIFACT, streams)
    return Problem(bin_types=tuple(paper_ec2_catalog()), items=items)


def _speedup(name: str, us: float) -> str:
    base = SEED_BASELINE_US.get(name)
    return f" speedup_vs_seed={base / us:.1f}x" if base and us > 0 else ""


def run() -> dict:
    out = {}
    for n in (4, 8, 12, 16):
        p = _fleet(n, seed=n)
        t_exact = time_us(lambda: solve(p, max_nodes=60_000), iters=1)
        sol, stats = solve(p, max_nodes=60_000)
        t_ffd = time_us(lambda: first_fit_decreasing(p), iters=3)
        ffd = first_fit_decreasing(p)
        t_af = time_us(lambda: solve_arcflow(p), iters=1)
        af, af_stats = solve_arcflow(p)
        gap = (ffd.cost - sol.cost) / sol.cost if sol.cost else 0.0
        record(
            f"solver/n{n}/exact", t_exact,
            f"cost=${sol.cost:.3f} nodes={stats.nodes} optimal={stats.optimal}"
            + _speedup(f"solver/n{n}/exact", t_exact),
        )
        record(
            f"solver/n{n}/arcflow", t_af,
            f"cost=${af.cost:.3f} patterns={af_stats.n_patterns} "
            f"classes={af_stats.n_classes} agree={abs(af.cost-sol.cost)<1e-6}"
            + _speedup(f"solver/n{n}/arcflow", t_af),
        )
        record(f"solver/n{n}/ffd", t_ffd,
               f"cost=${ffd.cost:.3f} gap_vs_exact={gap:.1%}"
               + _speedup(f"solver/n{n}/ffd", t_ffd))
        out[n] = {"exact": sol.cost, "ffd": ffd.cost, "arcflow": af.cost}

    # Mid-size fleets: arc-flow DP (exact; identical-stream grouping keeps
    # the demand lattice small — this is why the paper's VPSolver scales).
    for n in (24, 48, 96):
        p = _fleet(n, seed=n)
        t_af, (af, af_stats) = _timed(lambda: solve_arcflow(p))
        ffd = first_fit_decreasing(p)
        record(
            f"solver/n{n}/arcflow_only", t_af,
            f"cost=${af.cost:.3f} ffd=${ffd.cost:.3f} "
            f"gain_vs_ffd={(ffd.cost - af.cost) / ffd.cost:.0%} "
            f"optimal={af_stats.optimal}",
        )
        out[n] = {"arcflow": af.cost, "ffd": ffd.cost}

    # Large-fleet frontier (seed implementation topped out at n=96 / 16):
    # n=200 exact (budgeted B&B returns the incumbent), n=200/n=500
    # multi-kind arc-flow (which certifies its gap against the covering-LP
    # lower bound when the state budget is hit), and a 10-class n=500
    # catalog on the budgeted B&B + heuristics.
    p200 = _fleet(200, seed=200, n_kinds=5)
    t_exact, (sol, stats) = _timed(lambda: solve(p200, max_nodes=20_000))
    record(
        "solver/n200k5/exact", t_exact,
        f"cost=${sol.cost:.3f} nodes={stats.nodes} optimal={stats.optimal}",
    )
    out["200exact"] = {"exact": sol.cost}
    for n, kinds, budget in ((200, 5, 40_000), (500, 5, 40_000)):
        p = _fleet(n, seed=n, n_kinds=kinds)
        t_af, (af, af_stats) = _timed(
            lambda: solve_arcflow(p, max_dp_states=budget)
        )
        af.validate()
        ffd = first_fit_decreasing(p)
        gap = (
            (af.cost - af_stats.lp_bound) / af_stats.lp_bound
            if af_stats.lp_bound > 0
            else 0.0
        )
        record(
            f"solver/n{n}k{kinds}/arcflow", t_af,
            f"cost=${af.cost:.3f} ffd=${ffd.cost:.3f} lp_bound=${af_stats.lp_bound:.3f} "
            f"gap<={gap:.2%} states={af_stats.dp_states} optimal={af_stats.optimal}",
        )
        out[f"{n}k{kinds}"] = {"arcflow": af.cost, "ffd": ffd.cost,
                               "lp_bound": af_stats.lp_bound}
    p10 = _fleet(500, seed=500, n_kinds=10)
    t_ffd, ffd10 = _timed(lambda: first_fit_decreasing(p10))
    t_bc, (bc10, bc_stats) = _timed(lambda: solve(p10, max_nodes=5_000))
    record(
        "solver/n500k10/ffd", t_ffd,
        f"cost=${ffd10.cost:.3f} bins={len(ffd10.bins)}",
    )
    record(
        "solver/n500k10/exact_budget", t_bc,
        f"cost=${bc10.cost:.3f} nodes={bc_stats.nodes} optimal={bc_stats.optimal} "
        f"gain_vs_ffd={(ffd10.cost - bc10.cost) / ffd10.cost:.0%}",
    )
    out["500k10"] = {"ffd": ffd10.cost, "exact_budget": bc10.cost}

    meta = dict(_colgen_ladder(out))
    meta.update(_calibrated_ladder(out))
    meta.update(_pricing_kernel_bench())
    meta["seed_baseline_us"] = SEED_BASELINE_US
    write_json("BENCH_solver.json", prefix="solver/", meta=meta)
    return out


def _gap_vs(cost: float, lb: float) -> float:
    return (cost - lb) / lb if lb > 0 else 0.0


def _colgen_ladder(out: dict) -> dict:
    """Branch-and-price vs budgeted enumeration, n=200/500 x 4/8/10 kinds.

    Both solvers' gaps are measured against *colgen's* Farley-certified
    lower bound: it is admissible regardless of pricing convergence,
    whereas truncated-enumeration arc-flow has no honest bound of its own
    at 10 kinds.  Headline gate: at n=500/k=10 colgen certifies <= 1%
    where enumeration strands >= 5% above the same bound.
    """
    meta = {}
    for n, kinds in ((200, 4), (200, 8), (500, 4), (500, 8), (500, 10)):
        p = _fleet(n, seed=n, n_kinds=kinds)
        t_cg, (cg, cg_stats) = _timed(lambda: solve_colgen(p))
        cg.validate()
        cg_gap = _gap_vs(cg.cost, cg_stats.lp_bound)
        t_af, (af, af_stats) = _timed(
            lambda: solve_arcflow(p, max_dp_states=5_000, max_patterns=3_000)
        )
        af_gap = _gap_vs(af.cost, cg_stats.lp_bound)
        record(
            f"solver/n{n}k{kinds}/colgen", t_cg,
            f"cost=${cg.cost:.3f} lb=${cg_stats.lp_bound:.3f} gap<={cg_gap:.2%} "
            f"optimal={cg_stats.optimal} pricing_rounds={cg_stats.pricing_rounds} "
            f"columns_generated={cg_stats.columns_generated} "
            f"patterns={cg_stats.n_patterns}",
        )
        record(
            f"solver/n{n}k{kinds}/arcflow_budget", t_af,
            f"cost=${af.cost:.3f} gap_vs_colgen_lb={af_gap:.2%} "
            f"patterns_enumerated={af_stats.patterns_enumerated} "
            f"patterns_kept={af_stats.n_patterns} "
            f"colgen_slowdown={t_cg / t_af:.0f}x",
        )
        out[f"colgen_n{n}k{kinds}"] = {
            "colgen": cg.cost, "colgen_lb": cg_stats.lp_bound,
            "arcflow_budget": af.cost,
        }
        if (n, kinds) == (500, 10):
            meta["colgen_gap_n500k10"] = cg_gap
            meta["arcflow_budget_gap_n500k10"] = af_gap
    return meta


def _calibrated_ladder(out: dict) -> dict:
    """Branch-and-price on *calibrated* fleets (``solver/cal_*`` rows).

    Same solvers, same budgets as `_colgen_ladder`, but every requirement
    vector is a calibrated profile (`_calibrated_fleet`) — the vectors the
    fleet layer actually packs, regenerable via `scripts/recalibrate.py`.
    Gate: colgen certifies <= 1% on the calibrated n=500 / 10-kind fleet
    (measured 0.0%: real program mixes carry far more identical-stream
    structure than the adversarial synthetic kinds, so both solvers land
    near the bound — which is exactly the point of measuring on them).
    """
    meta = {}
    for n, kinds in ((200, 6), (500, 4), (500, 10)):
        p = _calibrated_fleet(n, n_kinds=kinds)
        t_cg, (cg, cg_stats) = _timed(lambda: solve_colgen(p))
        cg.validate()
        cg_gap = _gap_vs(cg.cost, cg_stats.lp_bound)
        t_af, (af, af_stats) = _timed(
            lambda: solve_arcflow(p, max_dp_states=5_000, max_patterns=3_000)
        )
        af_gap = _gap_vs(af.cost, cg_stats.lp_bound)
        record(
            f"solver/cal_n{n}k{kinds}/colgen", t_cg,
            f"cost=${cg.cost:.3f} lb=${cg_stats.lp_bound:.3f} "
            f"gap<={cg_gap:.2%} optimal={cg_stats.optimal} "
            f"pricing_rounds={cg_stats.pricing_rounds}",
        )
        record(
            f"solver/cal_n{n}k{kinds}/arcflow_budget", t_af,
            f"cost=${af.cost:.3f} gap_vs_colgen_lb={af_gap:.2%} "
            f"patterns_kept={af_stats.n_patterns}",
        )
        out[f"cal_n{n}k{kinds}"] = {
            "colgen": cg.cost, "colgen_lb": cg_stats.lp_bound,
            "arcflow_budget": af.cost,
        }
        if (n, kinds) == (500, 10):
            meta["colgen_gap_calibrated_n500k10"] = cg_gap
    return meta


def _pricing_kernel_bench() -> dict:
    """One batched pricing dispatch vs the serial per-kind numpy loop.

    Workload: the calibrated n=500 / 10-kind fleet's pricing grid, 16
    branch nodes x bin kinds (a dive frontier's worth).  The baseline
    is the kernel's numpy reference — a Python loop over the batch rows
    on identical inputs — so the speedup isolates what the single fused
    `lax.scan` dispatch buys.  Also probes jax-vs-numpy bit-equivalence
    on this workload and pallas-vs-numpy on a trimmed one (interpret-mode
    pallas is itself a Python loop, far too slow for the full grid).
    """
    from repro.core.binpack import colgen
    from repro.core.binpack.arcflow import group_items
    from repro.kernels import knapsack

    p = _calibrated_fleet(500, n_kinds=10)
    class_reqs, _demands, _members = group_items(p)
    grid = colgen._discretize(p, class_reqs, 32_768)
    kinds = grid.weights.shape[0]
    nodes = 16
    rng = np.random.RandomState(0)
    duals = rng.uniform(0.01, 0.3, size=(nodes, len(class_reqs)))
    vals = np.repeat(duals[:, grid.entry_class], kinds, axis=0)
    weights = np.tile(grid.weights, (nodes, 1, 1))
    bounds = np.tile(grid.fit, (nodes, 1))
    caps = np.tile(grid.cap_levels, (nodes, 1))

    def run(impl):
        return knapsack.price_knapsacks(vals, weights, bounds, caps, impl=impl)

    ref = run("numpy")
    if not knapsack.HAS_JAX:
        record("solver/pricing/serial_numpy", 0.0, "jax unavailable: skipped")
        return {"pricing_batched_speedup": float("nan"),
                "pricing_bitident_mismatch": float("nan")}
    jx = run("jax")  # warm (jit compile outside the timed call)
    mismatch = float(
        np.abs(np.asarray(jx.best) - ref.best).max()
        + np.abs(np.asarray(jx.counts) - ref.counts).max()
    )
    # Pallas on a trimmed grid (first 2 nodes, ~4k states).
    small = colgen._discretize(p, class_reqs, 4_096)
    sv = np.repeat(duals[:2, small.entry_class], kinds, axis=0)
    sw = np.tile(small.weights, (2, 1, 1))
    sb = np.tile(small.fit, (2, 1))
    sc = np.tile(small.cap_levels, (2, 1))
    pl_res = knapsack.price_knapsacks(sv, sw, sb, sc, impl="pallas")
    np_res = knapsack.price_knapsacks(sv, sw, sb, sc, impl="numpy")
    mismatch += float(
        np.abs(np.asarray(pl_res.best) - np_res.best).max()
        + np.abs(np.asarray(pl_res.counts) - np_res.counts).max()
    )
    t_serial = time_us(lambda: run("numpy"), iters=1, warmup=0)
    t_batch = time_us(lambda: run("jax"), iters=3, warmup=1)
    speedup = t_serial / t_batch if t_batch > 0 else float("inf")
    record(
        "solver/pricing/serial_numpy", t_serial,
        f"B={vals.shape[0]} E={vals.shape[1]} states={ref.states} "
        f"steps={ref.steps} (reference loop over batch rows)",
    )
    record(
        "solver/pricing/batched_jax", t_batch,
        f"one lax.scan dispatch, speedup_vs_serial={speedup:.1f}x "
        f"bitident_mismatch={mismatch:.1g}",
    )
    return {
        "pricing_batched_speedup": speedup,
        "pricing_bitident_mismatch": mismatch,
    }
