"""Beyond-paper: solver scaling study — exact vs arc-flow DP vs heuristics.

Random heterogeneous fleets of growing size; reports solve time and cost
gap of FFD vs the exact optimum (quantifying what the paper's exact
formulation buys over a greedy allocator).
"""
from __future__ import annotations

import numpy as np

from repro.core.binpack import (
    BinType, Choice, Item, Problem,
    first_fit_decreasing, solve, solve_arcflow,
)

from .common import record, time_us

CATALOG = (
    BinType("c4.2xlarge", (8, 15, 0, 0), 0.419),
    BinType("c4.8xlarge", (36, 60, 0, 0), 1.675),
    BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650),
)


def _fleet(n: int, seed: int, n_kinds: int = 3):
    """n streams drawn from n_kinds profiles (identical-item structure
    mirrors real camera fleets and feeds the arc-flow grouping)."""
    rng = np.random.RandomState(seed)
    kinds = []
    for k in range(n_kinds):
        cpu = rng.uniform(1.0, 5.0)
        kinds.append((
            (cpu, rng.uniform(0.2, 1.0), 0.0, 0.0),
            (cpu * 0.13, rng.uniform(0.2, 1.0), rng.uniform(30, 300),
             rng.uniform(0.1, 0.6)),
        ))
    items = []
    for i in range(n):
        c, g = kinds[i % n_kinds]
        items.append(Item(f"s{i}", (Choice("cpu", c), Choice("accel", g))))
    return Problem(bin_types=CATALOG, items=tuple(items))


def run() -> dict:
    out = {}
    for n in (4, 8, 12, 16):
        p = _fleet(n, seed=n)
        t_exact = time_us(lambda: solve(p, max_nodes=60_000), iters=1)
        sol, stats = solve(p, max_nodes=60_000)
        t_ffd = time_us(lambda: first_fit_decreasing(p), iters=3)
        ffd = first_fit_decreasing(p)
        t_af = time_us(lambda: solve_arcflow(p), iters=1)
        af, af_stats = solve_arcflow(p)
        gap = (ffd.cost - sol.cost) / sol.cost if sol.cost else 0.0
        record(
            f"solver/n{n}/exact", t_exact,
            f"cost=${sol.cost:.3f} nodes={stats.nodes} optimal={stats.optimal}",
        )
        record(
            f"solver/n{n}/arcflow", t_af,
            f"cost=${af.cost:.3f} patterns={af_stats.n_patterns} "
            f"classes={af_stats.n_classes} agree={abs(af.cost-sol.cost)<1e-6}",
        )
        record(f"solver/n{n}/ffd", t_ffd,
               f"cost=${ffd.cost:.3f} gap_vs_exact={gap:.1%}")
        out[n] = {"exact": sol.cost, "ffd": ffd.cost, "arcflow": af.cost}
    # Large fleets: arc-flow DP only (exact; identical-stream grouping keeps
    # the demand lattice small — this is why the paper's VPSolver scales).
    for n in (24, 48, 96):
        p = _fleet(n, seed=n)
        t_af = time_us(lambda: solve_arcflow(p), iters=1)
        af, af_stats = solve_arcflow(p)
        ffd = first_fit_decreasing(p)
        record(
            f"solver/n{n}/arcflow_only", t_af,
            f"cost=${af.cost:.3f} ffd=${ffd.cost:.3f} "
            f"gain_vs_ffd={(ffd.cost - af.cost) / ffd.cost:.0%}",
        )
        out[n] = {"arcflow": af.cost, "ffd": ffd.cost}
    return out
