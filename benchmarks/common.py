"""Shared benchmark utilities."""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str, prefix: str = "", meta: dict | None = None) -> str:
    """Dump recorded rows (optionally filtered by name prefix) as JSON.

    The artifact is the stable interface for `scripts/perf_diff.py`:
    {"meta": {...}, "rows": {name: {"us": float, "derived": str}}}.
    """
    rows = {
        name: {"us": us, "derived": derived}
        for name, us, derived in ROWS
        if name.startswith(prefix)
    }
    payload = {"meta": meta or {}, "rows": rows}
    p = pathlib.Path(path)
    p.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return str(p)


def time_us(fn, *, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def block(x):
    if hasattr(x, "block_until_ready"):
        x.block_until_ready()
    elif isinstance(x, (tuple, list)):
        for v in x:
            block(v)
    elif isinstance(x, dict):
        for v in x.values():
            block(v)
    return x
