"""Shared benchmark utilities."""
from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_us(fn, *, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def block(x):
    if hasattr(x, "block_until_ready"):
        x.block_until_ready()
    elif isinstance(x, (tuple, list)):
        for v in x:
            block(v)
    elif isinstance(x, dict):
        for v in x.values():
            block(v)
    return x
