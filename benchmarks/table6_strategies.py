"""Paper Table 6: allocation strategies x scenarios — the headline result.

Reproduces every cell (costs, instance counts, the scenario-3 ST1 failure)
and the 61% / 36% / 3% savings, timing the exact solver per cell.
"""
from __future__ import annotations

from repro.core.binpack import BinType, InfeasibleError
from repro.core.manager import ResourceManager
from repro.core.profiler import paper_profile_table
from repro.core.strategies import ALL_STRATEGIES
from repro.core.streams import AnalysisProgram, StreamSpec

from .common import record, time_us

VGG = AnalysisProgram("VGG-16", "vgg16")
ZF = AnalysisProgram("ZF", "zf")
CATALOG = (
    BinType("c4.2xlarge", (8, 15, 0, 0), 0.419),
    BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650),
)
SCENARIOS = {
    1: [StreamSpec("v1", VGG, 0.25)] + [StreamSpec(f"z{i}", ZF, 0.55) for i in range(3)],
    2: [StreamSpec("v1", VGG, 0.20), StreamSpec("z1", ZF, 0.50)],
    3: [StreamSpec(f"v{i}", VGG, 0.20) for i in range(2)]
       + [StreamSpec(f"z{i}", ZF, 8.0) for i in range(10)],
}
PAPER_COSTS = {
    (1, "ST1"): 1.676, (1, "ST2"): 0.650, (1, "ST3"): 0.650,
    (2, "ST1"): 0.419, (2, "ST2"): 0.650, (2, "ST3"): 0.419,
    (3, "ST1"): None, (3, "ST2"): 7.150, (3, "ST3"): 6.919,
}


def run() -> dict:
    mgr = ResourceManager(CATALOG, paper_profile_table())
    out = {}
    for sid, streams in SCENARIOS.items():
        costs = {}
        for strat in ALL_STRATEGIES:
            try:
                us = time_us(lambda: mgr.allocate(streams, strat), iters=3)
                plan = mgr.allocate(streams, strat)
                costs[strat.name] = plan.hourly_cost
                paper = PAPER_COSTS[(sid, strat.name)]
                match = (paper is not None
                         and abs(plan.hourly_cost - paper) < 1e-3)
                record(
                    f"table6/s{sid}/{strat.name}", us,
                    f"cost=${plan.hourly_cost:.3f} paper=${paper} "
                    f"match={match} instances={plan.instance_counts()}",
                )
            except InfeasibleError:
                costs[strat.name] = None
                record(f"table6/s{sid}/{strat.name}", 0.0,
                       f"FAIL paper={PAPER_COSTS[(sid, strat.name)]} match=True")
        out[sid] = costs
    # Savings summary (paper: 61%, 36%, 3%).
    s = out
    sav1 = 1 - s[1]["ST3"] / s[1]["ST1"]
    sav2 = 1 - s[2]["ST3"] / s[2]["ST2"]
    sav3 = 1 - s[3]["ST3"] / s[3]["ST2"]
    record("table6/savings", 0.0,
           f"s1={sav1:.0%}(paper 61%) s2={sav2:.0%}(paper 36%) "
           f"s3={sav3:.1%}(paper 3%)")
    return out
