"""Beyond-paper: instance lifecycle & billing study on timed churn traces.

Four experiments over the discrete-event `simulate_churn` replay and the
`core.lifecycle` billing engine, all on 500-stream fleets:

1. **Snapshot bit-identity** (regression): the consolidation benchmark's
   removal-heavy 200-event trace, replayed under `PinningPolicy` with
   per-second billing and zero boot latency, must reproduce the stored
   ``BENCH_policy.json`` snapshot-cost timeline bit for bit — the timed
   refactor may not perturb the PR-3 cost semantics — and its billed
   total must match the instantaneous $/hr integral.

2. **Billing-granularity ablation**: the same growth trace replayed under
   hourly vs per-second billing quantifies the hourly round-up premium
   (always >= 0: quantization only rounds up).

3. **Acting autoscaler vs reactive pinning**: on a bursty join-heavy
   timed trace with a 2-minute boot latency, `ActingAutoscaler` holds
   warm spares ahead of an oracle join forecast; joins then land on
   already-booted instances.  Gated: post-join degraded stream-seconds
   drop vs the reactive controller at <= 5% billed-cost overhead.

4. **Billing-aware vs billing-blind consolidation**: hourly billing on
   the removal-heavy trace; `ConsolidationPolicy(billing_horizon=1h)`
   rejects evacuations whose quantum is already sunk.  Gated: the aware
   policy never ends with a larger bill than the blind one.

Emits ``BENCH_lifecycle.json``, gated by ``scripts/check_bench.py``.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core.catalog import paper_ec2_catalog
from repro.core.lifecycle import BillingModel
from repro.core.manager import ResourceManager
from repro.core.policy import ActingAutoscaler, ConsolidationPolicy, PinningPolicy
from repro.core.profiler import paper_profile_table
from repro.core.simulator import simulate_churn
from repro.core.streams import (
    StreamAdded,
    StreamForecast,
    StreamSpec,
    synthetic_timed_trace,
)

from . import consolidation
from .common import record, write_json

BOOT_H = 2.0 / 60.0  # 2-minute instance boot latency
HOURLY = BillingModel(boot_hours=BOOT_H, quantum_hours=1.0)
PER_SECOND = BillingModel(boot_hours=BOOT_H, quantum_hours=0.0)
SNAPSHOT = BillingModel(boot_hours=0.0, quantum_hours=0.0)  # PR-3 semantics

GROWTH_EVENTS = 90
GROWTH_GAP_H = 0.03  # ~2.7 h span: several hourly quanta
LOOKAHEAD_H = 0.15  # oracle forecast window for the acting autoscaler
MAX_SPARES = 3
GAP_THRESHOLD = 0.3  # wide: keep both compared replays on the warm path

_REPO = pathlib.Path(__file__).resolve().parent.parent


def _replay(initial, trace, *, policy, billing, max_nodes=None):
    mgr = ResourceManager(
        paper_ec2_catalog(),
        paper_profile_table(),
        max_nodes=max_nodes or consolidation.MAX_NODES,
    )
    mgr.controller(gap_threshold=GAP_THRESHOLD)
    return simulate_churn(
        mgr,
        initial,
        trace,
        paper_profile_table(),
        policy=policy,
        billing=billing,
    )


def _growth_trace(initial):
    """Bursty join-heavy growth: the arrival pattern pre-provisioning is
    judged on (joins that must open fresh instances, mid-quantum)."""
    rng = np.random.RandomState(2618)
    kinds = consolidation.KINDS

    def make_join(i):
        return StreamSpec(f"g{i}", *kinds[i % len(kinds)])

    return synthetic_timed_trace(
        initial,
        rng,
        n_events=GROWTH_EVENTS,
        mean_gap_hours=GROWTH_GAP_H,
        p_join=0.6,
        p_leave=0.15,
        make_join=make_join,
        rerate_fps=lambda s: [
            fps
            for prog, fps in kinds
            if prog.program_id == s.program.program_id
        ],
        burst=3,
    )


def _oracle_forecast(trace):
    """Perfect short-horizon join forecaster read off the trace itself."""
    adds = [(ev.at, ev.stream) for ev in trace if isinstance(ev, StreamAdded)]

    def forecast(fleet, event):
        now = event.at if event is not None else 0.0
        live = {s.name for s in fleet}
        upcoming = tuple(
            s
            for t, s in adds
            if now < t <= now + LOOKAHEAD_H and s.name not in live
        )
        return StreamForecast(joins=upcoming[:MAX_SPARES])

    return forecast


def _post_join_degraded(out) -> float:
    """Degraded stream-seconds excluding the initial reset boot (identical
    across policies: every instance boots once at t=0)."""
    reset_wait = out["timeline"][0]["boot_wait_stream_hours"] * 3600.0
    return out["degraded_stream_seconds"] - reset_wait


def run() -> dict:
    initial = consolidation._initial_fleet()
    rng = np.random.RandomState(1802)  # the consolidation bench's seed
    removal_trace = consolidation._trace(list(initial), rng)

    # ---- 1. snapshot bit-identity under per-second / zero-boot billing
    t0 = time.perf_counter()
    pin = _replay(initial, removal_trace, policy=PinningPolicy(), billing=SNAPSHOT)
    pin_s = time.perf_counter() - t0
    stored = json.load(open(_REPO / "BENCH_policy.json"))["meta"]
    final = pin["timeline"][-1]["cost"]
    bitident_delta = abs(final - stored["final_cost_pinning"])
    integral_delta = abs(
        pin["billed_cost"] - pin["snapshot_cost_integral"]
    ) / max(pin["snapshot_cost_integral"], 1e-12)
    record(
        "lifecycle/pinning_bitident", pin_s * 1e6,
        f"final=${final:.2f} stored=${stored['final_cost_pinning']:.2f} "
        f"delta={bitident_delta:g} billed-integral={integral_delta:.2e}",
    )

    # ---- 2. hourly vs per-second billing ablation (same replay, re-billed)
    growth = _growth_trace(initial)
    reactive = {}
    for name, billing in (("hourly", HOURLY), ("per_second", PER_SECOND)):
        t0 = time.perf_counter()
        reactive[name] = _replay(
            initial, growth, policy=PinningPolicy(), billing=billing
        )
        record(
            f"lifecycle/reactive_{name}", (time.perf_counter() - t0) * 1e6,
            f"billed=${reactive[name]['billed_cost']:.2f} "
            f"integral=${reactive[name]['snapshot_cost_integral']:.2f} "
            f"degraded={_post_join_degraded(reactive[name]):.0f}s",
        )
    hourly_premium = (
        reactive["hourly"]["billed_cost"] / reactive["per_second"]["billed_cost"]
        - 1.0
    )

    # ---- 3. acting autoscaler vs reactive pinning (hourly billing)
    t0 = time.perf_counter()
    acting = _replay(
        initial,
        growth,
        policy=ActingAutoscaler(
            forecast=_oracle_forecast(growth), max_spares=MAX_SPARES
        ),
        billing=HOURLY,
    )
    acting_s = time.perf_counter() - t0
    deg_reactive = _post_join_degraded(reactive["hourly"])
    deg_acting = _post_join_degraded(acting)
    degraded_reduction = 1.0 - deg_acting / max(deg_reactive, 1e-12)
    overhead = acting["billed_cost"] / reactive["hourly"]["billed_cost"] - 1.0
    provisions = sum(
        a.startswith("autoscale:provision")
        for t in acting["timeline"]
        for a in t["actions"]
    )
    record(
        "lifecycle/acting_autoscaler", acting_s * 1e6,
        f"degraded={deg_acting:.0f}s vs reactive={deg_reactive:.0f}s "
        f"(-{degraded_reduction:.0%}) billed=${acting['billed_cost']:.2f} "
        f"overhead={overhead:+.2%} spares_provisioned={provisions}",
    )

    # ---- 4. billing-aware vs billing-blind consolidation (hourly billing)
    runs = {}
    for name, policy in (
        ("blind", ConsolidationPolicy(max_migrations=3)),
        ("aware", ConsolidationPolicy(max_migrations=3, billing_horizon=1.0)),
    ):
        t0 = time.perf_counter()
        runs[name] = _replay(initial, removal_trace, policy=policy, billing=HOURLY)
        rejects = sum(
            a.startswith("billed-reject")
            for t in runs[name]["timeline"]
            for a in t["actions"]
        )
        record(
            f"lifecycle/consolidation_{name}", (time.perf_counter() - t0) * 1e6,
            f"billed=${runs[name]['billed_cost']:.2f} "
            f"final=${runs[name]['final_cost']:.2f} "
            f"consolidations={runs[name]['consolidations']} "
            f"billed_rejects={rejects}",
        )
    aware_excess = (
        runs["aware"]["billed_cost"] / runs["blind"]["billed_cost"] - 1.0
    )

    out = {
        "pinning_bitident_delta": bitident_delta,
        "persecond_billed_integral_delta": integral_delta,
        "hourly_premium": hourly_premium,
        "degraded_reduction": degraded_reduction,
        "degraded_seconds_reactive": deg_reactive,
        "degraded_seconds_acting": deg_acting,
        "acting_billed_overhead": overhead,
        "billed_cost_reactive": reactive["hourly"]["billed_cost"],
        "billed_cost_acting": acting["billed_cost"],
        "billed_cost_consolidation_blind": runs["blind"]["billed_cost"],
        "billed_cost_consolidation_aware": runs["aware"]["billed_cost"],
        "billing_aware_excess": aware_excess,
        "spares_provisioned": provisions,
    }
    record(
        "lifecycle/summary", 0.0,
        f"premium={hourly_premium:.1%} degraded -{degraded_reduction:.0%} "
        f"overhead={overhead:+.2%} aware_excess={aware_excess:+.3%}",
    )
    write_json(
        "BENCH_lifecycle.json",
        prefix="lifecycle/",
        meta={
            "n_streams": consolidation.N_STREAMS,
            "n_removal_events": consolidation.N_EVENTS,
            "n_growth_events": GROWTH_EVENTS,
            "boot_hours": BOOT_H,
            "lookahead_hours": LOOKAHEAD_H,
            "max_spares": MAX_SPARES,
            **out,
        },
    )
    return out
