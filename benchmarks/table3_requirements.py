"""Paper Table 3: CPU/GPU requirements of VGG-16 and ZF at 0.2 FPS.

Reports the paper's published utilization vectors (the profile table used
by the scenario reproduction) AND a live-measured CPU profile on this host
via the manager's real test-run machinery.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.profiler import (
    TPU_V5E,
    derive_accelerator_profile,
    measure_cpu_profile,
    paper_profile_table,
)
from repro.core.streams import FrameSize
from repro.models.analysis_programs import PROGRAMS, make_frame, program_flops

from .common import record, time_us


def run() -> dict:
    out = {}
    table = paper_profile_table()
    fsz = FrameSize(640, 480)
    for prog in ("vgg16", "zf"):
        cpu = table.get(prog, "640x480", "cpu")
        acc = table.get(prog, "640x480", "accel")
        record(
            f"table3/{prog}/paper", 0.0,
            f"cpu_run_cores={cpu.requirement[0]:.2f} "
            f"accel_run_cores={acc.requirement[0]:.2f} "
            f"accel_units={acc.requirement[2]:.1f}",
        )
        # Live test run (the paper's §3.1.1 procedure, real wall-clock).
        fn = PROGRAMS[prog]
        measured = measure_cpu_profile(
            prog, fsz, lambda f: fn(jnp.asarray(f)), make_frame,
            memory_gb=0.9 if prog == "vgg16" else 0.55,
            n_warmup=1, n_iters=2,
        )
        derived = derive_accelerator_profile(
            prog, fsz,
            flops_per_frame=program_flops(prog, fsz),
            bytes_per_frame=program_flops(prog, fsz) * 0.05,
            memory_gb=0.5, cpu_profile=measured, roofline=TPU_V5E,
        )
        record(
            f"table3/{prog}/measured", 0.0,
            f"cpu_cores@0.2fps={measured.requirement[0]:.3f} "
            f"max_cpu_fps={measured.max_fps:.2f} "
            f"accel_tflops@0.2fps={derived.requirement[2]:.3f} "
            f"max_accel_fps={derived.max_fps:.1f}",
        )
        out[prog] = {"paper": cpu.requirement, "measured": measured.requirement}
    return out
