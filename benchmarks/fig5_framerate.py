"""Paper Fig. 5: desired frame rate vs resource utilization vs performance.

Sweeps VGG-16 (accelerator execution) across frame rates; utilization comes
from the manager's linear model, performance from the fleet simulator —
reproducing the knee where CPU overutilization degrades performance.
"""
from __future__ import annotations

import numpy as np

from repro.core.binpack import BinType
from repro.core.profiler import paper_profile_table
from repro.core.simulator import simulate_instance

from .common import record

GPU_BOX = BinType("g2.2xlarge", (8, 15, 1536, 4), 0.650)


def run() -> dict:
    table = paper_profile_table()
    prof = table.get("vgg16", "640x480", "accel")
    rows = []
    for fps in (0.5, 1.0, 2.0, 4.0, 8.0, 12.0, 16.0):
        req = prof.at_fps(fps)
        info = simulate_instance(GPU_BOX, [req])
        rows.append((fps, info.utilization[0], info.utilization[2],
                     info.performance))
    # Linearity check on the under-utilized prefix.
    fps_a, cpu_a = rows[0][0], rows[0][1]
    fps_b, cpu_b = rows[2][0], rows[2][1]
    linear = abs(cpu_b / cpu_a - fps_b / fps_a) < 1e-6
    knee = next((f for f, c, g, p in rows if p < 1.0), None)
    for fps, cpu, gpu, perf in rows:
        record(
            f"fig5/vgg16@{fps}fps", 0.0,
            f"cpu_util={cpu:.2f} gpu_util={gpu:.3f} performance={perf:.2f}",
        )
    record("fig5/summary", 0.0, f"linear={linear} perf_knee_fps={knee}")
    return {"rows": rows, "linear": linear, "knee": knee}
